//! Wire messages between master and workers.
//!
//! Trace propagation: requests that start remote work (`task.run`,
//! `peer.prepare`/`peer.run`, `shuffle.fetch_multi`/`fetch_batch`,
//! `broadcast.fetch`, `job.submit`) carry an optional
//! [`TraceContext`] the receiver parents its spans under, and the
//! result messages (`master.plan_result`, `master.peer_result`) ship
//! completed [`SpanRec`]s back. With tracing off the context is `None`
//! and the span vectors are empty — one tag byte / varint on the wire.

use crate::error::Result;
use crate::ser::{Decode, Encode, Reader, Value};
use crate::trace::{SpanRec, TraceContext};

/// Worker → master: registration.
#[derive(Debug, Clone, PartialEq)]
pub struct RegisterReq {
    pub addr: String,
    pub slots: u64,
}

impl Encode for RegisterReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.addr.encode(buf);
        self.slots.encode(buf);
    }
}
impl Decode for RegisterReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(RegisterReq { addr: String::decode(r)?, slots: u64::decode(r)? })
    }
}

/// Master → worker: registration reply.
#[derive(Debug, Clone, PartialEq)]
pub struct RegisterResp {
    pub worker_id: u64,
}

impl Encode for RegisterResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.worker_id.encode(buf);
    }
}
impl Decode for RegisterResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(RegisterResp { worker_id: u64::decode(r)? })
    }
}

/// Worker → master: liveness.
#[derive(Debug, Clone, PartialEq)]
pub struct Heartbeat {
    pub worker_id: u64,
}

impl Encode for Heartbeat {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.worker_id.encode(buf);
    }
}
impl Decode for Heartbeat {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(Heartbeat { worker_id: u64::decode(r)? })
    }
}

/// Master → worker: launch ranks of a named parallel function. Carries
/// the rank→worker-address mapping the paper distributes with tasks.
#[derive(Debug, Clone, PartialEq)]
pub struct LaunchReq {
    pub job_id: u64,
    pub fn_name: String,
    pub world_size: u64,
    pub ranks: Vec<u64>,
    pub rank_table: Vec<(u64, String)>,
    pub arg: Value,
    pub relay_mode: bool,
    /// Job-scoped base context id (isolates messages across jobs).
    pub context: u64,
}

impl Encode for LaunchReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.job_id.encode(buf);
        self.fn_name.encode(buf);
        self.world_size.encode(buf);
        self.ranks.encode(buf);
        self.rank_table.encode(buf);
        self.arg.encode(buf);
        self.relay_mode.encode(buf);
        self.context.encode(buf);
    }
}
impl Decode for LaunchReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(LaunchReq {
            job_id: u64::decode(r)?,
            fn_name: String::decode(r)?,
            world_size: u64::decode(r)?,
            ranks: Vec::<u64>::decode(r)?,
            rank_table: Vec::<(u64, String)>::decode(r)?,
            arg: Value::decode(r)?,
            relay_mode: bool::decode(r)?,
            context: u64::decode(r)?,
        })
    }
}

/// Worker → master: one rank's result.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskResult {
    pub job_id: u64,
    pub rank: usize,
    pub ok: bool,
    pub value: Value,
    pub error: String,
}

impl Encode for TaskResult {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.job_id.encode(buf);
        self.rank.encode(buf);
        self.ok.encode(buf);
        self.value.encode(buf);
        self.error.encode(buf);
    }
}
impl Decode for TaskResult {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(TaskResult {
            job_id: u64::decode(r)?,
            rank: usize::decode(r)?,
            ok: bool::decode(r)?,
            value: Value::decode(r)?,
            error: String::decode(r)?,
        })
    }
}

/// Worker → master: a completed map output of a shuffle lives here.
#[derive(Debug, Clone, PartialEq)]
pub struct ShuffleRegister {
    pub shuffle: u64,
    pub map_idx: u64,
    pub total_maps: u64,
    /// The worker's RPC address serving `shuffle.fetch` for this block.
    pub addr: String,
    /// Framed byte size of each registered bucket as
    /// `(reduce_idx, bytes)` pairs — what the master's locality-aware
    /// reduce placement sums per worker.
    pub bucket_bytes: Vec<(u64, u64)>,
}

impl Encode for ShuffleRegister {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.shuffle.encode(buf);
        self.map_idx.encode(buf);
        self.total_maps.encode(buf);
        self.addr.encode(buf);
        self.bucket_bytes.encode(buf);
    }
}
impl Decode for ShuffleRegister {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShuffleRegister {
            shuffle: u64::decode(r)?,
            map_idx: u64::decode(r)?,
            total_maps: u64::decode(r)?,
            addr: String::decode(r)?,
            bucket_bytes: Vec::<(u64, u64)>::decode(r)?,
        })
    }
}

/// Worker → master: where do the map outputs of `shuffle` live?
#[derive(Debug, Clone, PartialEq)]
pub struct ShuffleLocateReq {
    pub shuffle: u64,
}

impl Encode for ShuffleLocateReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.shuffle.encode(buf);
    }
}
impl Decode for ShuffleLocateReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShuffleLocateReq { shuffle: u64::decode(r)? })
    }
}

/// Master → worker: the map-output table for one shuffle (possibly still
/// incomplete — the caller checks `locations.len()` against `total_maps`).
#[derive(Debug, Clone, PartialEq)]
pub struct ShuffleLocateResp {
    pub total_maps: u64,
    pub locations: Vec<(u64, String)>,
}

impl Encode for ShuffleLocateResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.total_maps.encode(buf);
        self.locations.encode(buf);
    }
}
impl Decode for ShuffleLocateResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShuffleLocateResp {
            total_maps: u64::decode(r)?,
            locations: Vec::<(u64, String)>::decode(r)?,
        })
    }
}

/// Reduce task → remote worker: pull one shuffle bucket by block id.
#[derive(Debug, Clone, PartialEq)]
pub struct ShuffleFetchReq {
    pub shuffle: u64,
    pub map_idx: u64,
    pub reduce_idx: u64,
}

impl Encode for ShuffleFetchReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.shuffle.encode(buf);
        self.map_idx.encode(buf);
        self.reduce_idx.encode(buf);
    }
}
impl Decode for ShuffleFetchReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShuffleFetchReq {
            shuffle: u64::decode(r)?,
            map_idx: u64::decode(r)?,
            reduce_idx: u64::decode(r)?,
        })
    }
}

/// Remote worker → reduce task: the bucket's encoded bytes, or `None`
/// when the worker no longer holds the block (triggers recompute).
#[derive(Debug, Clone, PartialEq)]
pub struct ShuffleFetchResp {
    pub bytes: Option<Vec<u8>>,
}

impl Encode for ShuffleFetchResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.bytes.encode(buf);
    }
}
impl Decode for ShuffleFetchResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShuffleFetchResp { bytes: Option::<Vec<u8>>::decode(r)? })
    }
}

/// Reduce task → remote worker (`shuffle.fetch_multi`): pull several of
/// one worker's buckets for a single reduce partition in one round-trip.
/// `batch_bytes` bounds the response frame — the server fills buckets in
/// request order until the budget is spent (always at least one), and
/// the client re-asks for the remainder, so a giant shuffle streams in
/// bounded frames instead of ballooning one RPC response.
#[derive(Debug, Clone, PartialEq)]
pub struct ShuffleFetchMultiReq {
    pub shuffle: u64,
    pub reduce_idx: u64,
    pub map_idxs: Vec<u64>,
    pub batch_bytes: u64,
    pub ctx: Option<TraceContext>,
}

impl Encode for ShuffleFetchMultiReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.shuffle.encode(buf);
        self.reduce_idx.encode(buf);
        self.map_idxs.encode(buf);
        self.batch_bytes.encode(buf);
        self.ctx.encode(buf);
    }
}
impl Decode for ShuffleFetchMultiReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShuffleFetchMultiReq {
            shuffle: u64::decode(r)?,
            reduce_idx: u64::decode(r)?,
            map_idxs: Vec::<u64>::decode(r)?,
            batch_bytes: u64::decode(r)?,
            ctx: Option::<TraceContext>::decode(r)?,
        })
    }
}

/// Remote worker → reduce task: one `shuffle.fetch_multi` frame — a
/// prefix of the requested buckets (in request order), each `None` when
/// the worker no longer holds it (triggers recompute on the caller).
#[derive(Debug, Clone, PartialEq)]
pub struct ShuffleFetchMultiResp {
    pub buckets: Vec<(u64, Option<Vec<u8>>)>,
}

impl Encode for ShuffleFetchMultiResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.buckets.encode(buf);
    }
}
impl Decode for ShuffleFetchMultiResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShuffleFetchMultiResp { buckets: Vec::<(u64, Option<Vec<u8>>)>::decode(r)? })
    }
}

/// Master → worker (`task.run`): run a batch of stage tasks of a shipped
/// plan. `plan` is the canonical encoding of the whole
/// [`crate::rdd::PlanSpec`]; `shuffle_id` selects which stage to run —
/// `Some(id)` means "run map tasks of that shuffle node", `None` means
/// "compute final partitions and return their rows". `tasks` are the
/// global partition indices assigned to this worker. The handler acks
/// immediately and executes asynchronously, reporting through
/// [`PlanTaskResult`] (the launch/result split every long-running worker
/// endpoint uses, because RPC handlers must not block).
#[derive(Debug, Clone, PartialEq)]
pub struct PlanTaskReq {
    pub job_id: u64,
    pub plan: Vec<u8>,
    pub shuffle_id: Option<u64>,
    pub tasks: Vec<u64>,
    /// The dispatching stage span — worker task spans parent under it.
    pub ctx: Option<TraceContext>,
}

impl Encode for PlanTaskReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.job_id.encode(buf);
        self.plan.encode(buf);
        self.shuffle_id.encode(buf);
        self.tasks.encode(buf);
        self.ctx.encode(buf);
    }
}
impl Decode for PlanTaskReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(PlanTaskReq {
            job_id: u64::decode(r)?,
            plan: Vec::<u8>::decode(r)?,
            shuffle_id: Option::<u64>::decode(r)?,
            tasks: Vec::<u64>::decode(r)?,
            ctx: Option::<TraceContext>::decode(r)?,
        })
    }
}

/// Worker → master (`master.plan_result`): **per-task** stage reporting.
/// Each finished task sends one message with `results` carrying its
/// single `(task index, rows)` pair (rows empty for map tasks, whose
/// output went into the shuffle plane instead), so a straggler no longer
/// holds a whole worker batch hostage — the master's per-task slots fill
/// as tasks land and `plan.task.latency` is observable per task. A batch
/// that fails (after the worker's own retries) sends one `ok: false`
/// message with no results. `recoverable` classifies a failure on the
/// worker side (where the typed error still exists): `true` means the
/// driver may re-run the stage on the surviving workers, `false` means a
/// deterministic task failure that retrying cannot fix.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanTaskResult {
    pub job_id: u64,
    pub worker_id: u64,
    pub ok: bool,
    pub error: String,
    pub recoverable: bool,
    pub results: Vec<(u64, Vec<Value>)>,
    /// Completed worker-side spans piggy-backed to the master (empty
    /// when tracing is off or nothing finished since the last report).
    pub spans: Vec<SpanRec>,
}

impl Encode for PlanTaskResult {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.job_id.encode(buf);
        self.worker_id.encode(buf);
        self.ok.encode(buf);
        self.error.encode(buf);
        self.recoverable.encode(buf);
        self.results.encode(buf);
        self.spans.encode(buf);
    }
}
impl Decode for PlanTaskResult {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(PlanTaskResult {
            job_id: u64::decode(r)?,
            worker_id: u64::decode(r)?,
            ok: bool::decode(r)?,
            error: String::decode(r)?,
            recoverable: bool::decode(r)?,
            results: Vec::<(u64, Vec<Value>)>::decode(r)?,
            spans: Vec::<SpanRec>::decode(r)?,
        })
    }
}

/// Master → worker (`peer.prepare` then `peer.run`): launch this
/// worker's share of a gang-scheduled peer section. Each phase carries
/// only what it reads, so no payload crosses a worker's wire twice per
/// attempt: `plan` (the whole encoded [`crate::rdd::PlanSpec`]) ships
/// only in `run`, `rank_table` (the master-built rank → worker-address
/// map pushed into the worker's `ClusterTransport`) only in `prepare`;
/// `peer_id` names the `PeerOp` node to run; `ranks` are the
/// communicator ranks (= partition indices) placed on this worker;
/// `generation` is the gang attempt number — it feeds the communicator
/// context ([`crate::peer::peer_context`]) so a restarted gang can never
/// match messages from an aborted attempt. Two-phase like parallel-fn
/// launch: `prepare` hosts mailboxes and installs the table, `run`
/// spawns the rank threads, and no `run` is sent until every worker
/// acked `prepare`.
#[derive(Debug, Clone, PartialEq)]
pub struct PeerTaskReq {
    pub job_id: u64,
    pub peer_id: u64,
    pub generation: u64,
    pub plan: Vec<u8>,
    pub world_size: u64,
    pub ranks: Vec<u64>,
    pub rank_table: Vec<(u64, String)>,
    /// The gang's stage span — worker rank spans parent under it.
    pub ctx: Option<TraceContext>,
}

impl Encode for PeerTaskReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.job_id.encode(buf);
        self.peer_id.encode(buf);
        self.generation.encode(buf);
        self.plan.encode(buf);
        self.world_size.encode(buf);
        self.ranks.encode(buf);
        self.rank_table.encode(buf);
        self.ctx.encode(buf);
    }
}
impl Decode for PeerTaskReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(PeerTaskReq {
            job_id: u64::decode(r)?,
            peer_id: u64::decode(r)?,
            generation: u64::decode(r)?,
            plan: Vec::<u8>::decode(r)?,
            world_size: u64::decode(r)?,
            ranks: Vec::<u64>::decode(r)?,
            rank_table: Vec::<(u64, String)>::decode(r)?,
            ctx: Option::<TraceContext>::decode(r)?,
        })
    }
}

/// Worker → master (`master.peer_result`): one gang rank finished. Rank
/// results are reported individually (unlike `task.run`'s per-worker
/// batches) because the master aborts the WHOLE gang on the first
/// failure — it must not wait for a worker's other ranks, which may be
/// blocked in collectives against the failed one. A report from an
/// aborted attempt (stale `job_id`) is ignored by the master.
#[derive(Debug, Clone, PartialEq)]
pub struct PeerTaskResult {
    pub job_id: u64,
    pub worker_id: u64,
    pub rank: u64,
    pub generation: u64,
    pub ok: bool,
    pub error: String,
    pub recoverable: bool,
    /// Completed worker-side spans piggy-backed to the master.
    pub spans: Vec<SpanRec>,
}

impl Encode for PeerTaskResult {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.job_id.encode(buf);
        self.worker_id.encode(buf);
        self.rank.encode(buf);
        self.generation.encode(buf);
        self.ok.encode(buf);
        self.error.encode(buf);
        self.recoverable.encode(buf);
        self.spans.encode(buf);
    }
}
impl Decode for PeerTaskResult {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(PeerTaskResult {
            job_id: u64::decode(r)?,
            worker_id: u64::decode(r)?,
            rank: u64::decode(r)?,
            generation: u64::decode(r)?,
            ok: bool::decode(r)?,
            error: String::decode(r)?,
            recoverable: bool::decode(r)?,
            spans: Vec::<SpanRec>::decode(r)?,
        })
    }
}

/// Driver → master and master → workers (`shuffle.clear`): the shuffles
/// of a finished job — prune the master's map-output table and drop the
/// workers' local buckets so long-lived clusters don't grow unboundedly.
#[derive(Debug, Clone, PartialEq)]
pub struct ShuffleClear {
    pub shuffles: Vec<u64>,
}

impl Encode for ShuffleClear {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.shuffles.encode(buf);
    }
}
impl Decode for ShuffleClear {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShuffleClear { shuffles: Vec::<u64>::decode(r)? })
    }
}

/// Worker (or driver) → master: this process holds blocks of a broadcast
/// value — record it in the block-location table so later fetchers can
/// pull from it peer-to-peer. `blocks` empty means "every block of the
/// value" (the classic after-assembly registration); a non-empty list
/// registers just those blocks, which is how a mid-assembly fetcher
/// becomes a holder of each block *as it lands* instead of only after
/// the whole value is assembled.
#[derive(Debug, Clone, PartialEq)]
pub struct BroadcastRegister {
    pub id: u64,
    pub num_blocks: u64,
    pub total_bytes: u64,
    /// The holder's RPC address serving `broadcast.fetch`.
    pub addr: String,
    /// Block indices held (empty = all `num_blocks`).
    pub blocks: Vec<u64>,
}

impl Encode for BroadcastRegister {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.id.encode(buf);
        self.num_blocks.encode(buf);
        self.total_bytes.encode(buf);
        self.addr.encode(buf);
        self.blocks.encode(buf);
    }
}
impl Decode for BroadcastRegister {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(BroadcastRegister {
            id: u64::decode(r)?,
            num_blocks: u64::decode(r)?,
            total_bytes: u64::decode(r)?,
            addr: String::decode(r)?,
            blocks: Vec::<u64>::decode(r)?,
        })
    }
}

/// Worker → master: where do the blocks of broadcast `id` live?
#[derive(Debug, Clone, PartialEq)]
pub struct BroadcastLocateReq {
    pub id: u64,
}

impl Encode for BroadcastLocateReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.id.encode(buf);
    }
}
impl Decode for BroadcastLocateReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(BroadcastLocateReq { id: u64::decode(r)? })
    }
}

/// Master → worker: per-block holder addresses of one broadcast
/// (`num_blocks == 0` means the id is unknown — cleared or never
/// registered). The master/driver copy is always listed.
#[derive(Debug, Clone, PartialEq)]
pub struct BroadcastLocateResp {
    pub num_blocks: u64,
    pub total_bytes: u64,
    pub locations: Vec<(u64, Vec<String>)>,
}

impl Encode for BroadcastLocateResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.num_blocks.encode(buf);
        self.total_bytes.encode(buf);
        self.locations.encode(buf);
    }
}
impl Decode for BroadcastLocateResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(BroadcastLocateResp {
            num_blocks: u64::decode(r)?,
            total_bytes: u64::decode(r)?,
            locations: Vec::<(u64, Vec<String>)>::decode(r)?,
        })
    }
}

/// Fetcher → holder (`broadcast.fetch`): pull one block of a broadcast.
#[derive(Debug, Clone, PartialEq)]
pub struct BroadcastFetchReq {
    pub id: u64,
    pub block: u64,
    pub ctx: Option<TraceContext>,
}

impl Encode for BroadcastFetchReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.id.encode(buf);
        self.block.encode(buf);
        self.ctx.encode(buf);
    }
}
impl Decode for BroadcastFetchReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(BroadcastFetchReq {
            id: u64::decode(r)?,
            block: u64::decode(r)?,
            ctx: Option::<TraceContext>::decode(r)?,
        })
    }
}

/// Holder → fetcher: the block's bytes, or `None` when the holder no
/// longer has it (the fetcher falls back to the next holder).
#[derive(Debug, Clone, PartialEq)]
pub struct BroadcastFetchResp {
    pub bytes: Option<Vec<u8>>,
}

impl Encode for BroadcastFetchResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.bytes.encode(buf);
    }
}
impl Decode for BroadcastFetchResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(BroadcastFetchResp { bytes: Option::<Vec<u8>>::decode(r)? })
    }
}

/// Driver → master and master → workers (`broadcast.clear`): drop these
/// broadcasts everywhere (explicit `Broadcast::destroy`).
#[derive(Debug, Clone, PartialEq)]
pub struct BroadcastClear {
    pub broadcasts: Vec<u64>,
}

impl Encode for BroadcastClear {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.broadcasts.encode(buf);
    }
}
impl Decode for BroadcastClear {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(BroadcastClear { broadcasts: Vec::<u64>::decode(r)? })
    }
}

/// Driver → master and master → workers (`job.clear`): one plan job
/// ended (success or failure) — prune its shuffles from the map-output
/// table and its auto-created broadcasts from the block-location table,
/// and fan both out to workers in a single message so a failed job can't
/// leak one kind of state while cleaning the other.
#[derive(Debug, Clone, PartialEq)]
pub struct JobClear {
    pub shuffles: Vec<u64>,
    pub broadcasts: Vec<u64>,
}

impl Encode for JobClear {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.shuffles.encode(buf);
        self.broadcasts.encode(buf);
    }
}
impl Decode for JobClear {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(JobClear {
            shuffles: Vec::<u64>::decode(r)?,
            broadcasts: Vec::<u64>::decode(r)?,
        })
    }
}

/// Driver session → master (`job.submit`): run this encoded
/// [`crate::rdd::PlanSpec`] asynchronously under `session_id`'s share of
/// the slot ledger. The master acks with a [`JobSubmitResp`] immediately
/// and the session polls `job.status` — many sessions submit
/// concurrently and their stages interleave as capacity allows.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSubmitReq {
    pub session_id: u64,
    pub plan: Vec<u8>,
    /// Submitter-side parent span (e.g. a streaming batch) the job's
    /// root span links under.
    pub ctx: Option<TraceContext>,
}

impl Encode for JobSubmitReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.session_id.encode(buf);
        self.plan.encode(buf);
        self.ctx.encode(buf);
    }
}
impl Decode for JobSubmitReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(JobSubmitReq {
            session_id: u64::decode(r)?,
            plan: Vec::<u8>::decode(r)?,
            ctx: Option::<TraceContext>::decode(r)?,
        })
    }
}

/// Master → driver session: the submitted job's id.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSubmitResp {
    pub job_id: u64,
}

impl Encode for JobSubmitResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.job_id.encode(buf);
    }
}
impl Decode for JobSubmitResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(JobSubmitResp { job_id: u64::decode(r)? })
    }
}

/// Driver session → master (`job.status`): poll one submitted job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobStatusReq {
    pub job_id: u64,
}

impl Encode for JobStatusReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.job_id.encode(buf);
    }
}
impl Decode for JobStatusReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(JobStatusReq { job_id: u64::decode(r)? })
    }
}

/// Master → driver session: job state. `state` is the
/// [`crate::jobserver::JobState`] tag (0 pending, 1 running, 2 done,
/// 3 failed, 4 cancelled); `results` carries the collected rows once
/// done, `error` the failure message once failed.
#[derive(Debug, Clone, PartialEq)]
pub struct JobStatusResp {
    pub state: u8,
    pub error: String,
    pub tasks_completed: u64,
    pub results: Option<Vec<Value>>,
}

impl Encode for JobStatusResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.state.encode(buf);
        self.error.encode(buf);
        self.tasks_completed.encode(buf);
        self.results.encode(buf);
    }
}
impl Decode for JobStatusResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(JobStatusResp {
            state: u8::decode(r)?,
            error: String::decode(r)?,
            tasks_completed: u64::decode(r)?,
            results: Option::<Vec<Value>>::decode(r)?,
        })
    }
}

/// Driver session → master (`job.cancel`): stop a submitted job. The
/// stage scheduler observes the flag between dispatch rounds; already
/// running tasks finish on their workers but their results are dropped.
#[derive(Debug, Clone, PartialEq)]
pub struct JobCancelReq {
    pub job_id: u64,
}

impl Encode for JobCancelReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.job_id.encode(buf);
    }
}
impl Decode for JobCancelReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(JobCancelReq { job_id: u64::decode(r)? })
    }
}

/// Operator → master (`worker.drain`): gracefully retire a worker —
/// stop placing tasks and gang ranks on it, let what's running finish.
/// The worker process keeps serving shuffle/broadcast fetches until its
/// owner shuts it down, so its map outputs stay reachable.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerDrainReq {
    pub worker_id: u64,
}

impl Encode for WorkerDrainReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.worker_id.encode(buf);
    }
}
impl Decode for WorkerDrainReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(WorkerDrainReq { worker_id: u64::decode(r)? })
    }
}

/// Master → operator: drain acknowledged; `in_flight` is the number of
/// ledger slots the worker still holds (poll until 0 to retire it).
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerDrainResp {
    pub known: bool,
    pub in_flight: u64,
}

impl Encode for WorkerDrainResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.known.encode(buf);
        self.in_flight.encode(buf);
    }
}
impl Decode for WorkerDrainResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(WorkerDrainResp { known: bool::decode(r)?, in_flight: u64::decode(r)? })
    }
}

/// Task batch → remote worker (`shuffle.fetch_batch`): pull buckets for
/// a *whole batch of reduce tasks* from one peer in one stream, instead
/// of one `shuffle.fetch_multi` stream per task. `pairs` lists the
/// wanted `(map_idx, reduce_idx)` blocks across every reduce partition
/// the batch covers; like `fetch_multi`, `batch_bytes` bounds each
/// response frame (at least one bucket per frame) and the client re-asks
/// for the tail.
#[derive(Debug, Clone, PartialEq)]
pub struct ShuffleFetchBatchReq {
    pub shuffle: u64,
    pub pairs: Vec<(u64, u64)>,
    pub batch_bytes: u64,
    /// Calling task's span — the server ties fetch-side work to it.
    pub ctx: Option<TraceContext>,
}

impl Encode for ShuffleFetchBatchReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.shuffle.encode(buf);
        self.pairs.encode(buf);
        self.batch_bytes.encode(buf);
        self.ctx.encode(buf);
    }
}
impl Decode for ShuffleFetchBatchReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShuffleFetchBatchReq {
            shuffle: u64::decode(r)?,
            pairs: Vec::<(u64, u64)>::decode(r)?,
            batch_bytes: u64::decode(r)?,
            ctx: Option::<TraceContext>::decode(r)?,
        })
    }
}

/// Remote worker → task batch: one `shuffle.fetch_batch` frame — a
/// prefix of the requested `(map_idx, reduce_idx)` buckets in request
/// order, each `None` when the worker no longer holds it.
#[derive(Debug, Clone, PartialEq)]
pub struct ShuffleFetchBatchResp {
    pub buckets: Vec<((u64, u64), Option<Vec<u8>>)>,
}

impl Encode for ShuffleFetchBatchResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.buckets.encode(buf);
    }
}
impl Decode for ShuffleFetchBatchResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShuffleFetchBatchResp {
            buckets: Vec::<((u64, u64), Option<Vec<u8>>)>::decode(r)?,
        })
    }
}

/// Rank background writer → master (`master.ckpt.register`): one rank's
/// encoded snapshot for epoch `epoch` of peer section `peer_id`. `size`
/// is the gang's world size — the master needs it to decide when the
/// epoch is complete (all `size` ranks registered the same k).
#[derive(Debug, Clone, PartialEq)]
pub struct CkptRegister {
    pub peer_id: u64,
    pub size: u64,
    pub epoch: u64,
    pub rank: u64,
    pub bytes: Vec<u8>,
}

impl Encode for CkptRegister {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.peer_id.encode(buf);
        self.size.encode(buf);
        self.epoch.encode(buf);
        self.rank.encode(buf);
        self.bytes.encode(buf);
    }
}
impl Decode for CkptRegister {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(CkptRegister {
            peer_id: u64::decode(r)?,
            size: u64::decode(r)?,
            epoch: u64::decode(r)?,
            rank: u64::decode(r)?,
            bytes: Vec::<u8>::decode(r)?,
        })
    }
}

/// Master → rank writer: whether this registration completed the epoch.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptRegisterResp {
    pub complete: bool,
}

impl Encode for CkptRegisterResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.complete.encode(buf);
    }
}
impl Decode for CkptRegisterResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(CkptRegisterResp { complete: bool::decode(r)? })
    }
}

/// Restoring rank → master (`master.ckpt.locate`): fetch this rank's
/// snapshot. `epoch < 0` asks for the latest *complete* epoch; a
/// non-negative value pins the exact k every rank agreed on (rank 0
/// probes with -1, broadcasts the answer, the rest pin it).
#[derive(Debug, Clone, PartialEq)]
pub struct CkptLocateReq {
    pub peer_id: u64,
    pub rank: u64,
    pub epoch: i64,
}

impl Encode for CkptLocateReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.peer_id.encode(buf);
        self.rank.encode(buf);
        self.epoch.encode(buf);
    }
}
impl Decode for CkptLocateReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(CkptLocateReq {
            peer_id: u64::decode(r)?,
            rank: u64::decode(r)?,
            epoch: i64::decode(r)?,
        })
    }
}

/// Master → restoring rank: the snapshot, when a complete epoch exists.
/// Partial epochs are never served — `found` is false until all ranks
/// of some k have registered.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptLocateResp {
    pub found: bool,
    pub epoch: u64,
    pub bytes: Vec<u8>,
}

impl Encode for CkptLocateResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.found.encode(buf);
        self.epoch.encode(buf);
        self.bytes.encode(buf);
    }
}
impl Decode for CkptLocateResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(CkptLocateResp {
            found: bool::decode(r)?,
            epoch: u64::decode(r)?,
            bytes: Vec::<u8>::decode(r)?,
        })
    }
}

/// Recovering driver → master (`session.reattach`): look up the jobs
/// journaled under a previous driver incarnation's session id.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionReattachReq {
    pub session_id: u64,
}

impl Encode for SessionReattachReq {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.session_id.encode(buf);
    }
}
impl Decode for SessionReattachReq {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(SessionReattachReq { session_id: u64::decode(r)? })
    }
}

/// Master → recovering driver: the session's journaled jobs as
/// `(job_id, state tag)` pairs (tags as in [`JobStatusResp`]); empty /
/// `found: false` when the session id is unknown or already GC'd.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionReattachResp {
    pub found: bool,
    pub jobs: Vec<(u64, u8)>,
}

impl Encode for SessionReattachResp {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.found.encode(buf);
        self.jobs.encode(buf);
    }
}
impl Decode for SessionReattachResp {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(SessionReattachResp {
            found: bool::decode(r)?,
            jobs: Vec::<(u64, u8)>::decode(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ser::{from_bytes, to_bytes};

    #[test]
    fn launch_req_round_trip() {
        let req = LaunchReq {
            job_id: 3,
            fn_name: "app.fn".into(),
            world_size: 8,
            ranks: vec![0, 2, 4],
            rank_table: vec![(0, "127.0.0.1:1".into()), (1, "127.0.0.1:2".into())],
            arg: Value::Map(vec![("n".into(), Value::I64(5))]),
            relay_mode: true,
            context: 3 << 20,
        };
        let back: LaunchReq = from_bytes(&to_bytes(&req)).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn task_result_round_trip_ok_and_err() {
        for (ok, value, error) in [
            (true, Value::F64(1.5), String::new()),
            (false, Value::Unit, "rank exploded".to_string()),
        ] {
            let tr = TaskResult { job_id: 1, rank: 7, ok, value, error };
            let back: TaskResult = from_bytes(&to_bytes(&tr)).unwrap();
            assert_eq!(back, tr);
        }
    }

    #[test]
    fn shuffle_plane_messages_round_trip() {
        let reg = ShuffleRegister {
            shuffle: 9,
            map_idx: 2,
            total_maps: 4,
            addr: "127.0.0.1:4000".into(),
            bucket_bytes: vec![(0, 128), (2, 4096)],
        };
        assert_eq!(from_bytes::<ShuffleRegister>(&to_bytes(&reg)).unwrap(), reg);

        let req = ShuffleLocateReq { shuffle: 9 };
        assert_eq!(from_bytes::<ShuffleLocateReq>(&to_bytes(&req)).unwrap(), req);

        let resp = ShuffleLocateResp {
            total_maps: 4,
            locations: vec![(0, "127.0.0.1:1".into()), (2, "127.0.0.1:2".into())],
        };
        assert_eq!(from_bytes::<ShuffleLocateResp>(&to_bytes(&resp)).unwrap(), resp);

        let fetch = ShuffleFetchReq { shuffle: 9, map_idx: 1, reduce_idx: 3 };
        assert_eq!(from_bytes::<ShuffleFetchReq>(&to_bytes(&fetch)).unwrap(), fetch);

        for bytes in [None, Some(vec![1u8, 2, 3])] {
            let resp = ShuffleFetchResp { bytes };
            assert_eq!(from_bytes::<ShuffleFetchResp>(&to_bytes(&resp)).unwrap(), resp);
        }

        let multi = ShuffleFetchMultiReq {
            shuffle: 9,
            reduce_idx: 3,
            map_idxs: vec![0, 2, 5],
            batch_bytes: 1 << 20,
            ctx: Some(TraceContext { trace_id: 11, span_id: 12 }),
        };
        assert_eq!(from_bytes::<ShuffleFetchMultiReq>(&to_bytes(&multi)).unwrap(), multi);
        let resp = ShuffleFetchMultiResp {
            buckets: vec![(0, Some(vec![1, 2, 3])), (2, None), (5, Some(Vec::new()))],
        };
        assert_eq!(from_bytes::<ShuffleFetchMultiResp>(&to_bytes(&resp)).unwrap(), resp);
    }

    #[test]
    fn plan_task_messages_round_trip() {
        for (shuffle_id, ctx) in [
            (None, None),
            (Some(77u64), Some(TraceContext { trace_id: 42, span_id: 7 })),
        ] {
            let req = PlanTaskReq {
                job_id: 5,
                plan: vec![1, 2, 3, 4],
                shuffle_id,
                tasks: vec![0, 2, 5],
                ctx,
            };
            assert_eq!(from_bytes::<PlanTaskReq>(&to_bytes(&req)).unwrap(), req);
        }
        let ok = PlanTaskResult {
            job_id: 5,
            worker_id: 2,
            ok: true,
            error: String::new(),
            recoverable: false,
            results: vec![(0, vec![Value::I64(1)]), (2, Vec::new())],
            spans: vec![SpanRec {
                trace_id: 42,
                span_id: 9,
                parent_id: 7,
                kind: "task".into(),
                labels: vec![("task".into(), "0".into())],
                t_start_ns: 100,
                t_end_ns: 200,
                ok: true,
            }],
        };
        assert_eq!(from_bytes::<PlanTaskResult>(&to_bytes(&ok)).unwrap(), ok);
        let failed = PlanTaskResult {
            job_id: 6,
            worker_id: 1,
            ok: false,
            error: "op not registered".into(),
            recoverable: true,
            results: Vec::new(),
            spans: Vec::new(),
        };
        assert_eq!(from_bytes::<PlanTaskResult>(&to_bytes(&failed)).unwrap(), failed);

        let clear = ShuffleClear { shuffles: vec![9, 11] };
        assert_eq!(from_bytes::<ShuffleClear>(&to_bytes(&clear)).unwrap(), clear);
    }

    #[test]
    fn peer_section_messages_round_trip() {
        let req = PeerTaskReq {
            job_id: 12,
            peer_id: 900,
            generation: 2,
            plan: vec![5, 6, 7],
            world_size: 4,
            ranks: vec![1, 3],
            rank_table: vec![(0, "127.0.0.1:1".into()), (1, "127.0.0.1:2".into())],
            ctx: Some(TraceContext { trace_id: 5, span_id: 6 }),
        };
        assert_eq!(from_bytes::<PeerTaskReq>(&to_bytes(&req)).unwrap(), req);

        for (ok, error, recoverable) in
            [(true, String::new(), false), (false, "rank exploded".to_string(), true)]
        {
            let res = PeerTaskResult {
                job_id: 12,
                worker_id: 2,
                rank: 3,
                generation: 2,
                ok,
                error,
                recoverable,
                spans: vec![SpanRec {
                    trace_id: 5,
                    span_id: 8,
                    parent_id: 6,
                    kind: "peer.rank".into(),
                    labels: Vec::new(),
                    t_start_ns: 1,
                    t_end_ns: 2,
                    ok: true,
                }],
            };
            assert_eq!(from_bytes::<PeerTaskResult>(&to_bytes(&res)).unwrap(), res);
        }
    }

    #[test]
    fn broadcast_plane_messages_round_trip() {
        for blocks in [Vec::new(), vec![0u64, 2]] {
            let reg = BroadcastRegister {
                id: 21,
                num_blocks: 3,
                total_bytes: 1000,
                addr: "127.0.0.1:5000".into(),
                blocks,
            };
            assert_eq!(from_bytes::<BroadcastRegister>(&to_bytes(&reg)).unwrap(), reg);
        }

        let req = BroadcastLocateReq { id: 21 };
        assert_eq!(from_bytes::<BroadcastLocateReq>(&to_bytes(&req)).unwrap(), req);

        let resp = BroadcastLocateResp {
            num_blocks: 2,
            total_bytes: 640,
            locations: vec![
                (0, vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()]),
                (1, vec!["127.0.0.1:1".into()]),
            ],
        };
        assert_eq!(from_bytes::<BroadcastLocateResp>(&to_bytes(&resp)).unwrap(), resp);

        let fetch = BroadcastFetchReq { id: 21, block: 1, ctx: None };
        assert_eq!(from_bytes::<BroadcastFetchReq>(&to_bytes(&fetch)).unwrap(), fetch);
        for bytes in [None, Some(vec![9u8, 8, 7])] {
            let resp = BroadcastFetchResp { bytes };
            assert_eq!(from_bytes::<BroadcastFetchResp>(&to_bytes(&resp)).unwrap(), resp);
        }

        let clear = BroadcastClear { broadcasts: vec![21, 22] };
        assert_eq!(from_bytes::<BroadcastClear>(&to_bytes(&clear)).unwrap(), clear);

        let job = JobClear { shuffles: vec![9], broadcasts: vec![21] };
        assert_eq!(from_bytes::<JobClear>(&to_bytes(&job)).unwrap(), job);
    }

    #[test]
    fn job_server_messages_round_trip() {
        let submit = JobSubmitReq { session_id: 3, plan: vec![1, 2, 3], ctx: None };
        assert_eq!(from_bytes::<JobSubmitReq>(&to_bytes(&submit)).unwrap(), submit);
        let resp = JobSubmitResp { job_id: 17 };
        assert_eq!(from_bytes::<JobSubmitResp>(&to_bytes(&resp)).unwrap(), resp);

        let status = JobStatusReq { job_id: 17 };
        assert_eq!(from_bytes::<JobStatusReq>(&to_bytes(&status)).unwrap(), status);
        for (state, error, results) in [
            (1u8, String::new(), None),
            (2, String::new(), Some(vec![Value::I64(4), Value::Str("x".into())])),
            (3, "worker lost".to_string(), None),
        ] {
            let resp = JobStatusResp { state, error, tasks_completed: 9, results };
            assert_eq!(from_bytes::<JobStatusResp>(&to_bytes(&resp)).unwrap(), resp);
        }

        let cancel = JobCancelReq { job_id: 17 };
        assert_eq!(from_bytes::<JobCancelReq>(&to_bytes(&cancel)).unwrap(), cancel);

        let drain = WorkerDrainReq { worker_id: 2 };
        assert_eq!(from_bytes::<WorkerDrainReq>(&to_bytes(&drain)).unwrap(), drain);
        let dresp = WorkerDrainResp { known: true, in_flight: 3 };
        assert_eq!(from_bytes::<WorkerDrainResp>(&to_bytes(&dresp)).unwrap(), dresp);
    }

    #[test]
    fn shuffle_fetch_batch_round_trip() {
        let req = ShuffleFetchBatchReq {
            shuffle: 9,
            pairs: vec![(0, 1), (2, 1), (0, 3)],
            batch_bytes: 1 << 20,
            ctx: None,
        };
        assert_eq!(from_bytes::<ShuffleFetchBatchReq>(&to_bytes(&req)).unwrap(), req);
        let resp = ShuffleFetchBatchResp {
            buckets: vec![((0, 1), Some(vec![1, 2])), ((2, 1), None), ((0, 3), Some(Vec::new()))],
        };
        assert_eq!(from_bytes::<ShuffleFetchBatchResp>(&to_bytes(&resp)).unwrap(), resp);
    }

    #[test]
    fn register_and_heartbeat_round_trip() {
        let req = RegisterReq { addr: "127.0.0.1:9".into(), slots: 4 };
        assert_eq!(from_bytes::<RegisterReq>(&to_bytes(&req)).unwrap(), req);
        let resp = RegisterResp { worker_id: 12 };
        assert_eq!(from_bytes::<RegisterResp>(&to_bytes(&resp)).unwrap(), resp);
        let hb = Heartbeat { worker_id: 12 };
        assert_eq!(from_bytes::<Heartbeat>(&to_bytes(&hb)).unwrap(), hb);
    }

    #[test]
    fn checkpoint_plane_messages_round_trip() {
        let reg = CkptRegister { peer_id: 7, size: 4, epoch: 11, rank: 2, bytes: vec![1, 2, 3] };
        assert_eq!(from_bytes::<CkptRegister>(&to_bytes(&reg)).unwrap(), reg);
        for complete in [true, false] {
            let resp = CkptRegisterResp { complete };
            assert_eq!(from_bytes::<CkptRegisterResp>(&to_bytes(&resp)).unwrap(), resp);
        }

        for epoch in [-1i64, 0, 11] {
            let req = CkptLocateReq { peer_id: 7, rank: 2, epoch };
            assert_eq!(from_bytes::<CkptLocateReq>(&to_bytes(&req)).unwrap(), req);
        }
        let hit = CkptLocateResp { found: true, epoch: 11, bytes: vec![9, 8] };
        assert_eq!(from_bytes::<CkptLocateResp>(&to_bytes(&hit)).unwrap(), hit);
        let miss = CkptLocateResp { found: false, epoch: 0, bytes: Vec::new() };
        assert_eq!(from_bytes::<CkptLocateResp>(&to_bytes(&miss)).unwrap(), miss);
    }

    #[test]
    fn session_reattach_round_trip() {
        let req = SessionReattachReq { session_id: 5 };
        assert_eq!(from_bytes::<SessionReattachReq>(&to_bytes(&req)).unwrap(), req);
        let resp = SessionReattachResp { found: true, jobs: vec![(17, 2), (18, 1)] };
        assert_eq!(from_bytes::<SessionReattachResp>(&to_bytes(&resp)).unwrap(), resp);
        let gone = SessionReattachResp { found: false, jobs: Vec::new() };
        assert_eq!(from_bytes::<SessionReattachResp>(&to_bytes(&gone)).unwrap(), gone);
    }
}
