//! Vendored pure-Rust LZ-style block codec for shuffle buckets.
//!
//! The vendor set has no compression crate, so this is a small in-tree
//! LZ77 codec in the LZ4 block style: greedy hash-table matching over a
//! 64 KiB window, 4-byte minimum matches, and sequences of
//! `token | literal-run | literals | offset(u16 LE) | match-run`, where
//! the token packs a 4-bit literal count and a 4-bit `match length - 4`
//! (value 15 extends through 255-run bytes, exactly like LZ4). The final
//! sequence carries literals only — the decoder stops when the input is
//! exhausted after a literal run.
//!
//! On top of the raw codec sits the **bucket frame** every stored or
//! wire-shipped shuffle bucket wears: one tag byte (`FRAME_RAW` /
//! `FRAME_LZ`), and for compressed payloads a `u32` LE uncompressed
//! length. [`frame`] falls back to the raw tag whenever compression does
//! not win (incompressible data must never grow), so a frame is always
//! self-describing — readers need no config to decode, and clusters with
//! mixed `ignite.shuffle.compress` settings interoperate.

use crate::error::{IgniteError, Result};
use crate::metrics;
use std::borrow::Cow;
use std::sync::atomic::{AtomicU32, Ordering};

/// Frame tag: payload follows uncompressed.
pub const FRAME_RAW: u8 = 0;
/// Frame tag: `u32` LE uncompressed length, then the LZ stream.
pub const FRAME_LZ: u8 = 1;

const MIN_MATCH: usize = 4;
const MAX_OFFSET: usize = 65_535;
const HASH_BITS: u32 = 13;

#[inline]
fn hash4(src: &[u8], i: usize) -> usize {
    let v = u32::from_le_bytes([src[i], src[i + 1], src[i + 2], src[i + 3]]);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Append a 255-run extension length (LZ4 style).
fn emit_run(out: &mut Vec<u8>, mut extra: usize) {
    while extra >= 255 {
        out.push(255);
        extra -= 255;
    }
    out.push(extra as u8);
}

fn emit_sequence(out: &mut Vec<u8>, literals: &[u8], offset: usize, match_len: usize) {
    let lit = literals.len();
    let m = match_len - MIN_MATCH;
    out.push(((lit.min(15) as u8) << 4) | m.min(15) as u8);
    if lit >= 15 {
        emit_run(out, lit - 15);
    }
    out.extend_from_slice(literals);
    out.extend_from_slice(&(offset as u16).to_le_bytes());
    if m >= 15 {
        emit_run(out, m - 15);
    }
}

fn emit_trailing_literals(out: &mut Vec<u8>, literals: &[u8]) {
    if literals.is_empty() {
        return;
    }
    let lit = literals.len();
    out.push((lit.min(15) as u8) << 4);
    if lit >= 15 {
        emit_run(out, lit - 15);
    }
    out.extend_from_slice(literals);
}

/// Compress `src` into the LZ block stream. Always succeeds; worst case
/// the output is slightly larger than the input (callers gate with
/// [`frame`], which keeps the raw bytes when compression does not win).
pub fn compress(src: &[u8]) -> Vec<u8> {
    let n = src.len();
    let mut out = Vec::with_capacity(n / 2 + 16);
    if n < MIN_MATCH + 1 {
        emit_trailing_literals(&mut out, src);
        return out;
    }
    // Position table over 4-byte prefixes; entries store position + 1 so
    // 0 means "empty".
    let mut table = vec![0u32; 1 << HASH_BITS];
    let limit = n - MIN_MATCH;
    let mut i = 0usize;
    let mut anchor = 0usize;
    while i <= limit {
        let h = hash4(src, i);
        let cand = table[h] as usize;
        table[h] = (i + 1) as u32;
        if cand > 0 {
            let cand = cand - 1;
            let off = i - cand;
            if off > 0 && off <= MAX_OFFSET && src[cand..cand + MIN_MATCH] == src[i..i + MIN_MATCH]
            {
                let mut len = MIN_MATCH;
                while i + len < n && src[cand + len] == src[i + len] {
                    len += 1;
                }
                emit_sequence(&mut out, &src[anchor..i], off, len);
                i += len;
                anchor = i;
                continue;
            }
        }
        i += 1;
    }
    emit_trailing_literals(&mut out, &src[anchor..]);
    out
}

/// Decompress an LZ block stream produced by [`compress`], verifying the
/// output against `expected_len`. Malformed input is a `Codec` error,
/// never a panic or an out-of-bounds read.
pub fn decompress(src: &[u8], expected_len: usize) -> Result<Vec<u8>> {
    fn err(m: &str) -> IgniteError {
        IgniteError::Codec(format!("lz block: {m}"))
    }
    let mut out = Vec::with_capacity(expected_len);
    let mut i = 0usize;
    while i < src.len() {
        let token = src[i];
        i += 1;
        let mut lit = (token >> 4) as usize;
        if lit == 15 {
            loop {
                let b = *src.get(i).ok_or_else(|| err("truncated literal run"))?;
                i += 1;
                lit += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        if i + lit > src.len() {
            return Err(err("literal run past end of input"));
        }
        out.extend_from_slice(&src[i..i + lit]);
        i += lit;
        if i == src.len() {
            break; // final literal-only sequence
        }
        if i + 2 > src.len() {
            return Err(err("truncated match offset"));
        }
        let off = u16::from_le_bytes([src[i], src[i + 1]]) as usize;
        i += 2;
        if off == 0 || off > out.len() {
            return Err(err("match offset out of window"));
        }
        let mut mlen = (token & 0x0F) as usize + MIN_MATCH;
        if token & 0x0F == 15 {
            loop {
                let b = *src.get(i).ok_or_else(|| err("truncated match run"))?;
                i += 1;
                mlen += b as usize;
                if b != 255 {
                    break;
                }
            }
        }
        // Byte-at-a-time copy: offsets smaller than the match length are
        // legal run encodings and must replicate freshly-written bytes.
        let start = out.len() - off;
        for k in 0..mlen {
            let b = out[start + k];
            out.push(b);
        }
    }
    if out.len() != expected_len {
        return Err(err("decompressed length mismatch"));
    }
    Ok(out)
}

/// Wrap an encoded bucket into its storage/wire frame. With
/// `try_compress`, payloads that shrink (header included) get the
/// `FRAME_LZ` tag; everything else — compression off, tiny buckets,
/// incompressible data — ships raw behind `FRAME_RAW`.
pub fn frame(bytes: &[u8], try_compress: bool) -> Vec<u8> {
    if try_compress && bytes.len() > 64 && bytes.len() <= u32::MAX as usize {
        let comp = compress(bytes);
        if comp.len() + 5 < bytes.len() + 1 {
            let mut out = Vec::with_capacity(comp.len() + 5);
            out.push(FRAME_LZ);
            out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
            out.extend_from_slice(&comp);
            return out;
        }
    }
    let mut out = Vec::with_capacity(bytes.len() + 1);
    out.push(FRAME_RAW);
    out.extend_from_slice(bytes);
    out
}

/// Consecutive raw outcomes before [`AdaptiveGate`] stops attempting LZ.
const SKIP_AFTER_RAW: u32 = 16;
/// While the gate is closed, one frame in this many still attempts LZ,
/// so a workload that turns compressible reopens it.
const REPROBE_EVERY: u32 = 64;

/// Adaptive compression gate: tracks how recent [`frame`] attempts went
/// and, after [`SKIP_AFTER_RAW`] consecutive `FRAME_RAW` outcomes (the
/// LZ pass ran and lost), stops paying for the compression attempt —
/// incompressible workloads (already-compressed or random payloads)
/// otherwise burn a full LZ pass per bucket just to ship raw anyway.
/// One frame in [`REPROBE_EVERY`] is still attempted while closed, so a
/// shift back to compressible data reopens the gate. Skipped attempts
/// count on `shuffle.compress.skipped`. The streak is a heuristic:
/// updates are racy under concurrent map tasks, and a lost increment
/// only delays the gate, never corrupts a frame.
#[derive(Debug, Default)]
pub struct AdaptiveGate {
    /// Consecutive raw outcomes; past `SKIP_AFTER_RAW`, the overflow
    /// counts frames skipped while closed (for re-probe scheduling).
    raw_streak: AtomicU32,
}

impl AdaptiveGate {
    pub fn new() -> Self {
        AdaptiveGate::default()
    }

    /// Is the gate currently skipping LZ attempts?
    pub fn is_closed(&self) -> bool {
        self.raw_streak.load(Ordering::Relaxed) >= SKIP_AFTER_RAW
    }
}

/// [`frame`] behind an [`AdaptiveGate`]: identical output byte-for-byte
/// on every attempted frame, but once the gate closes the LZ pass is
/// skipped outright (raw tag, no compression attempt). Tiny buckets
/// (which [`frame`] never compresses) bypass the gate so they neither
/// open nor close it.
pub fn frame_adaptive(bytes: &[u8], try_compress: bool, gate: &AdaptiveGate) -> Vec<u8> {
    if !try_compress || bytes.len() <= 64 {
        return frame(bytes, false);
    }
    let streak = gate.raw_streak.load(Ordering::Relaxed);
    if streak >= SKIP_AFTER_RAW {
        let skips = streak - SKIP_AFTER_RAW;
        if (skips + 1) % REPROBE_EVERY != 0 {
            gate.raw_streak.store(streak.saturating_add(1), Ordering::Relaxed);
            metrics::global().counter("shuffle.compress.skipped").inc();
            return frame(bytes, false);
        }
    }
    let out = frame(bytes, true);
    match out.first() {
        Some(&FRAME_LZ) => gate.raw_streak.store(0, Ordering::Relaxed),
        _ => gate.raw_streak.store(streak.saturating_add(1), Ordering::Relaxed),
    }
    out
}

/// Recover a bucket's encoded bytes from its frame. Raw frames borrow
/// (no copy on the hot uncompressed path); compressed frames decompress.
pub fn unframe(framed: &[u8]) -> Result<Cow<'_, [u8]>> {
    match framed.first() {
        Some(&FRAME_RAW) => Ok(Cow::Borrowed(&framed[1..])),
        Some(&FRAME_LZ) => {
            if framed.len() < 5 {
                return Err(IgniteError::Codec("truncated compressed shuffle frame".into()));
            }
            let expected =
                u32::from_le_bytes([framed[1], framed[2], framed[3], framed[4]]) as usize;
            Ok(Cow::Owned(decompress(&framed[5..], expected)?))
        }
        Some(t) => Err(IgniteError::Codec(format!("unknown shuffle frame tag {t}"))),
        None => Err(IgniteError::Codec("empty shuffle frame".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn roundtrip(data: &[u8]) {
        let comp = compress(data);
        let back = decompress(&comp, data.len()).unwrap();
        assert_eq!(back, data, "lz round trip changed {} bytes", data.len());
    }

    #[test]
    fn empty_and_tiny_inputs_round_trip() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"abc");
        roundtrip(b"abcd");
        roundtrip(b"aaaaa");
    }

    #[test]
    fn repetitive_input_compresses_well() {
        let data: Vec<u8> = b"the quick brown fox ".iter().copied().cycle().take(4096).collect();
        let comp = compress(&data);
        assert!(comp.len() * 4 < data.len(), "20-byte cycle should shrink 4x+, got {}", comp.len());
        roundtrip(&data);
    }

    #[test]
    fn long_runs_use_extended_lengths() {
        // > 15 literals and > 19-byte matches exercise the 255-run paths.
        let mut data = Vec::new();
        for i in 0..64u8 {
            data.push(i); // 64 incompressible literals
        }
        data.extend(std::iter::repeat(7u8).take(1000)); // one long match run
        roundtrip(&data);
    }

    #[test]
    fn random_input_round_trips() {
        let mut rng = Xoshiro256::seeded(0xC0FFEE);
        for len in [1usize, 7, 100, 1000, 70_000] {
            let data: Vec<u8> = (0..len).map(|_| rng.next_below(256) as u8).collect();
            roundtrip(&data);
        }
    }

    #[test]
    fn mixed_input_round_trips() {
        let mut rng = Xoshiro256::seeded(42);
        let mut data = Vec::new();
        for _ in 0..200 {
            if rng.chance(0.5) {
                data.extend_from_slice(b"key-0000-padding-padding");
            } else {
                data.extend((0..rng.range(1, 30)).map(|_| rng.next_below(256) as u8));
            }
        }
        roundtrip(&data);
    }

    #[test]
    fn malformed_streams_error_cleanly() {
        assert!(decompress(&[0xF0], 100).is_err(), "truncated literal run");
        assert!(decompress(&[0x10], 1).is_err(), "literal past end");
        // literal + dangling offset byte
        assert!(decompress(&[0x11, b'x', 0x01], 5).is_err(), "truncated offset");
        // offset 0 is never valid
        assert!(decompress(&[0x01, 0x00, 0x00], 5).is_err(), "zero offset");
        // offset beyond what has been written
        assert!(decompress(&[0x10, b'x', 0x09, 0x00], 6).is_err(), "offset out of window");
    }

    #[test]
    fn frame_prefers_raw_when_compression_does_not_win() {
        let mut rng = Xoshiro256::seeded(9);
        let random: Vec<u8> = (0..512).map(|_| rng.next_below(256) as u8).collect();
        let framed = frame(&random, true);
        assert_eq!(framed[0], FRAME_RAW, "incompressible data must ship raw");
        assert_eq!(unframe(&framed).unwrap().as_ref(), &random[..]);

        let text: Vec<u8> = b"pad-pad-pad-".iter().copied().cycle().take(2048).collect();
        let framed = frame(&text, true);
        assert_eq!(framed[0], FRAME_LZ);
        assert!(framed.len() < text.len() / 2);
        assert_eq!(unframe(&framed).unwrap().as_ref(), &text[..]);

        // Compression disabled: always raw, and always decodable.
        let framed = frame(&text, false);
        assert_eq!(framed[0], FRAME_RAW);
        assert_eq!(unframe(&framed).unwrap().as_ref(), &text[..]);
    }

    #[test]
    fn unframe_rejects_garbage() {
        assert!(unframe(&[]).is_err());
        assert!(unframe(&[9, 1, 2]).is_err(), "unknown tag");
        assert!(unframe(&[FRAME_LZ, 1, 0]).is_err(), "truncated header");
    }

    #[test]
    fn adaptive_gate_closes_after_persistent_raw_outcomes() {
        let mut rng = Xoshiro256::seeded(0xADA9);
        let random: Vec<u8> = (0..512).map(|_| rng.next_below(256) as u8).collect();
        let gate = AdaptiveGate::new();
        let skipped = || crate::metrics::global().counter("shuffle.compress.skipped").get();
        let before = skipped();
        for _ in 0..16 {
            // Attempted, lost: identical to the plain framing path.
            let framed = frame_adaptive(&random, true, &gate);
            assert_eq!(framed, frame(&random, true));
            assert_eq!(framed[0], FRAME_RAW);
        }
        assert!(gate.is_closed(), "16 consecutive raw outcomes close the gate");
        let framed = frame_adaptive(&random, true, &gate);
        assert_eq!(framed[0], FRAME_RAW, "skipped frames still decode");
        assert_eq!(unframe(&framed).unwrap().as_ref(), &random[..]);
        // `>=`: the counter is global, and concurrent tests may skip too.
        assert!(skipped() >= before + 1, "the 17th frame skipped the LZ attempt");
    }

    #[test]
    fn adaptive_gate_reopens_on_compressible_reprobe() {
        let mut rng = Xoshiro256::seeded(0xADA10);
        let random: Vec<u8> = (0..512).map(|_| rng.next_below(256) as u8).collect();
        let text: Vec<u8> = b"pad-pad-pad-".iter().copied().cycle().take(2048).collect();
        let gate = AdaptiveGate::new();
        for _ in 0..16 {
            frame_adaptive(&random, true, &gate);
        }
        assert!(gate.is_closed());
        // The workload turns compressible: skipped frames still ship raw
        // until the scheduled re-probe (one in 64) wins and reopens.
        for i in 0..63 {
            let framed = frame_adaptive(&text, true, &gate);
            assert_eq!(framed[0], FRAME_RAW, "frame {i} rides the closed gate");
        }
        let framed = frame_adaptive(&text, true, &gate);
        assert_eq!(framed[0], FRAME_LZ, "the 64th frame re-probes and wins");
        assert!(!gate.is_closed(), "a winning probe reopens the gate");
        assert_eq!(frame_adaptive(&text, true, &gate)[0], FRAME_LZ);
    }

    #[test]
    fn adaptive_gate_ignores_tiny_and_uncompressed_frames() {
        let gate = AdaptiveGate::new();
        for _ in 0..100 {
            // ≤ 64 bytes: frame() never compresses, so the gate must not
            // learn from these.
            assert_eq!(frame_adaptive(b"tiny", true, &gate)[0], FRAME_RAW);
            // Compression off entirely: the gate is bypassed too.
            assert_eq!(frame_adaptive(&[7u8; 512], false, &gate)[0], FRAME_RAW);
        }
        assert!(!gate.is_closed(), "bypassed frames never close the gate");
    }
}
