//! Shuffle manager — materializes the stage boundaries the DAG scheduler
//! cuts ("a stage boundary is determined by when data needs to be shuffled
//! through the cluster", paper §2.2).
//!
//! Map tasks partition their output by key hash into `reduce`-side buckets
//! registered here. Since PR 1 the pipeline is **byte-oriented and
//! tiered**: buckets are encoded through the [`crate::ser`] codec at
//! registration, held in memory while a per-shuffle byte budget
//! (`ignite.shuffle.memory.bytes`) allows, **spilled** to the engine's
//! [`crate::storage::DiskStore`] past it, and — when the manager is wired
//! to a cluster via [`ShuffleNet`] — **fetched from remote workers** over
//! RPC. Reduce tasks see one API regardless of where the bytes live
//! (memory → disk → remote).
//!
//! PR 5 made the plane fast end-to-end:
//!
//! * **framing + compression** — every stored or wire-shipped bucket
//!   wears a self-describing [`compress`] frame; with
//!   `ignite.shuffle.compress` the frame holds an LZ-compressed payload
//!   (raw fallback when compression does not win), cutting memory, spill
//!   and network bytes at every boundary with one encode;
//! * **LRU demotion** — the memory tier no longer freezes its first
//!   residents: under budget pressure the least-recently-used buckets
//!   demote to the disk tier (`shuffle.evictions`) so hot buckets stay
//!   resident instead of forcing every new write straight to disk;
//! * **batched streaming fetch** — [`ShuffleManager::fetch_reduce_bytes`]
//!   pulls ALL of a reduce task's missing buckets from each remote worker
//!   through `shuffle.fetch_multi`, streamed in
//!   `ignite.shuffle.fetch.batch.bytes` response frames, collapsing
//!   remote round-trips from O(maps × reduces) to O(workers × reduces);
//! * **size-reporting registration** — [`ShuffleNet::register`] carries
//!   each map output's per-reduce framed byte sizes, which is what the
//!   master's locality-aware reduce placement sums per worker.
//!
//! The manager tracks per-shuffle completion so a finished map stage is
//! never re-run (and can be, if a fault wipes it — lineage recomputation
//! re-encodes and re-registers the buckets, including spilled ones).

pub mod compress;

use crate::error::{IgniteError, Result};
use crate::metrics;
use crate::ser::{from_bytes, to_bytes, Decode, Encode};
use crate::storage::DiskStore;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

// ------------------------------------------------------------ hashing --

/// Fixed seed for [`StableHasher`]. Never change this value: partition
/// assignment must agree across processes and releases, because a reduce
/// task on worker B fetches the bucket a map task on worker A wrote for
/// it — both sides must compute the same `partition(key)`.
const STABLE_SEED: u64 = 0x51_7C_C1_B7_27_22_0A_95;

/// FxHash-style 64-bit hasher with a **fixed, documented seed**.
///
/// `std::collections::hash_map::DefaultHasher` (SipHash-1-3) does not
/// guarantee a stable algorithm across Rust releases, so hashing a key in
/// two different binaries may disagree — fatal for cross-process shuffle.
/// This hasher is the classic Fx multiply-rotate-xor mix (as used by
/// rustc's FxHasher), fixed here byte-for-byte: state' =
/// `(rotl5(state) ^ word) * K` with `K = 0x51_7C_C1_B7_27_22_0A_95`,
/// words consumed as little-endian u64 chunks with a zero-padded tail.
/// Stability is locked by test vectors below.
#[derive(Debug, Clone)]
pub struct StableHasher {
    state: u64,
}

impl Default for StableHasher {
    fn default() -> Self {
        StableHasher::new()
    }
}

impl StableHasher {
    pub fn new() -> Self {
        StableHasher { state: STABLE_SEED }
    }

    #[inline]
    fn mix(&mut self, word: u64) {
        self.state = (self.state.rotate_left(5) ^ word).wrapping_mul(STABLE_SEED);
    }
}

impl Hasher for StableHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for chunk in &mut chunks {
            self.mix(u64::from_le_bytes(chunk.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            self.mix(u64::from_le_bytes(tail));
        }
        // Fold the length in so "ab"+"c" != "a"+"bc" across write calls
        // of prefix-sharing keys.
        self.mix(bytes.len() as u64);
    }

    // The default integer methods forward through native-endian bytes;
    // pin them to little-endian so big- and little-endian workers in one
    // cluster agree on partition assignment.
    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.write(&[n]);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.write(&n.to_le_bytes());
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.write(&n.to_le_bytes());
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.write(&n.to_le_bytes());
    }

    #[inline]
    fn write_u128(&mut self, n: u128) {
        self.write(&n.to_le_bytes());
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.write(&(n as u64).to_le_bytes());
    }

    #[inline]
    fn write_i8(&mut self, n: i8) {
        self.write_u8(n as u8);
    }

    #[inline]
    fn write_i16(&mut self, n: i16) {
        self.write_u16(n as u16);
    }

    #[inline]
    fn write_i32(&mut self, n: i32) {
        self.write_u32(n as u32);
    }

    #[inline]
    fn write_i64(&mut self, n: i64) {
        self.write_u64(n as u64);
    }

    #[inline]
    fn write_i128(&mut self, n: i128) {
        self.write_u128(n as u128);
    }

    #[inline]
    fn write_isize(&mut self, n: isize) {
        self.write_usize(n as usize);
    }
}

/// Deterministic hash partitioner (Spark's default), stable across
/// processes (see [`StableHasher`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HashPartitioner {
    pub partitions: usize,
}

impl HashPartitioner {
    pub fn new(partitions: usize) -> Self {
        assert!(partitions > 0, "need at least one partition");
        HashPartitioner { partitions }
    }

    pub fn partition<K: Hash>(&self, key: &K) -> usize {
        let mut h = StableHasher::new();
        key.hash(&mut h);
        (h.finish() % self.partitions as u64) as usize
    }
}

// ------------------------------------------------------- remote plane --

/// Locations of a shuffle's completed map outputs, as tracked by the
/// cluster master: map index → worker RPC address.
#[derive(Debug, Clone, Default)]
pub struct MapOutputs {
    pub total_maps: usize,
    pub locations: HashMap<usize, String>,
}

impl MapOutputs {
    /// All `total_maps` outputs are registered somewhere.
    pub fn is_complete(&self) -> bool {
        self.total_maps > 0 && self.locations.len() >= self.total_maps
    }

    pub fn addr_of(&self, map_idx: usize) -> Option<&str> {
        self.locations.get(&map_idx).map(String::as_str)
    }
}

/// Network hooks wiring a [`ShuffleManager`] into a cluster: registration
/// of completed map outputs with the master's map-output table, lookup of
/// bucket locations, and the bucket pulls themselves. Implemented over
/// RPC in [`crate::cluster`]; absent in pure local mode.
pub trait ShuffleNet: Send + Sync {
    /// Announce that this process holds map output `map_idx` of `shuffle`.
    /// `bucket_bytes` carries the framed size of each registered bucket
    /// as `(reduce_idx, bytes)` pairs — the per-worker byte totals the
    /// master's locality-aware reduce placement sums.
    fn register(
        &self,
        shuffle: u64,
        map_idx: usize,
        total_maps: usize,
        bucket_bytes: &[(usize, usize)],
    ) -> Result<()>;
    /// Ask the master where every map output of `shuffle` lives.
    fn locate(&self, shuffle: u64) -> Result<MapOutputs>;
    /// Fetch one bucket's framed bytes from the worker at `addr`.
    fn fetch(&self, addr: &str, shuffle: u64, map_idx: usize, reduce_idx: usize) -> Result<Vec<u8>>;
    /// Fetch several of one worker's buckets for a single reduce
    /// partition in one round-trip (`shuffle.fetch_multi`). A response
    /// frame is bounded by `batch_bytes`, so implementations may return
    /// fewer entries than requested (always at least one) — the caller
    /// re-asks for the remainder. `None` bytes mean the holder no longer
    /// has that bucket. The default implementation degrades to one
    /// [`fetch`](Self::fetch) per bucket for simple test nets.
    fn fetch_multi(
        &self,
        addr: &str,
        shuffle: u64,
        reduce_idx: usize,
        map_idxs: &[usize],
        batch_bytes: usize,
    ) -> Result<Vec<(usize, Option<Vec<u8>>)>> {
        let _ = batch_bytes;
        map_idxs
            .iter()
            .map(|&m| self.fetch(addr, shuffle, m, reduce_idx).map(|b| (m, Some(b))))
            .collect()
    }
    /// Fetch many `(map_idx, reduce_idx)` buckets of one shuffle from
    /// the worker at `addr` in one combined stream
    /// (`shuffle.fetch_batch`) — the cross-task generalization of
    /// [`fetch_multi`](Self::fetch_multi): one stream spans EVERY reduce
    /// partition a worker's task batch is about to merge, not just one
    /// task's, so a batch of R reduce tasks costs O(workers) streams
    /// instead of O(workers × R). Same streaming contract: a response
    /// frame is bounded by `batch_bytes` and may carry fewer pairs than
    /// asked (always at least one); `None` bytes mean the holder no
    /// longer has that bucket. The default degrades to one
    /// [`fetch`](Self::fetch) per bucket for simple test nets.
    fn fetch_pairs(
        &self,
        addr: &str,
        shuffle: u64,
        pairs: &[(usize, usize)],
        batch_bytes: usize,
    ) -> Result<Vec<((usize, usize), Option<Vec<u8>>)>> {
        let _ = batch_bytes;
        pairs
            .iter()
            .map(|&(m, r)| self.fetch(addr, shuffle, m, r).map(|b| ((m, r), Some(b))))
            .collect()
    }
    /// This process's own shuffle-serving address (skip self-fetch).
    fn local_addr(&self) -> String;
}

// ------------------------------------------------------------ manager --

type BlockKey = (u64, usize, usize);

fn block_id(shuffle: u64, map_idx: usize, reduce_idx: usize) -> String {
    format!("shuffle-{shuffle}-{map_idx}-{reduce_idx}")
}

/// Decode a framed bucket (see [`compress`]) back into typed rows — the
/// read-side twin of the encode+frame step in
/// [`ShuffleManager::put_bucket_bytes`].
pub fn decode_bucket<T: Decode>(framed: &[u8]) -> Result<Vec<T>> {
    let payload = compress::unframe(framed)?;
    from_bytes(&payload)
}

/// Default streaming frame budget for `shuffle.fetch_multi` responses
/// (`ignite.shuffle.fetch.batch.bytes`).
pub const DEFAULT_FETCH_BATCH_BYTES: usize = 1 << 20;

/// One resident bucket: framed bytes plus an LRU clock stamp.
struct MemBucket {
    bytes: Arc<Vec<u8>>,
    last_use: AtomicU64,
}

/// What the admission path decided to do with overflow, executed after
/// the buckets lock is released (disk I/O never runs under it).
enum Overflow {
    /// Demote these LRU residents to the disk tier.
    Demote(Vec<(BlockKey, Arc<Vec<u8>>)>),
    /// The new bucket cannot fit even after demoting everything: spill
    /// it directly.
    SpillNew(Vec<u8>),
}

/// Byte-oriented, tiered shuffle block registry (memory → disk → remote)
/// with optional LZ block compression and LRU demotion under pressure.
pub struct ShuffleManager {
    /// In-memory tier: framed buckets within the byte budget.
    buckets: RwLock<HashMap<BlockKey, MemBucket>>,
    /// Keys currently on the disk tier, with their framed byte size.
    spilled: Mutex<HashMap<BlockKey, usize>>,
    /// Per-(shuffle, map) framed bucket sizes, maintained at put/drop
    /// time so [`map_done`](ShuffleManager::map_done)'s locality report
    /// is O(reduces) instead of a scan of every bucket in every tier.
    /// Demotions don't touch it (the framed bytes are unchanged).
    sizes: Mutex<HashMap<(u64, usize), HashMap<usize, usize>>>,
    /// Spill tier; `None` in budget-unlimited unit-test setups.
    disk: Option<Arc<DiskStore>>,
    /// In-memory byte budget across all shuffles.
    budget: usize,
    mem_used: AtomicUsize,
    /// LRU clock for the memory tier.
    clock: AtomicU64,
    /// Compress bucket frames (`ignite.shuffle.compress`).
    compress: bool,
    /// Adaptive skip of LZ attempts on persistently incompressible
    /// buckets (see [`compress::AdaptiveGate`]).
    compress_gate: compress::AdaptiveGate,
    /// Streaming frame budget for batched remote fetches.
    batch_bytes: usize,
    /// Cluster plane; `None` in local mode.
    net: RwLock<Option<Arc<dyn ShuffleNet>>>,
    /// Cached master locate() answers (one RPC per shuffle, not per bucket).
    located: Mutex<HashMap<u64, MapOutputs>>,
    /// Completed map tasks per shuffle.
    done_maps: Mutex<HashMap<u64, std::collections::HashSet<usize>>>,
    /// Shuffles whose map stage has fully completed locally (with map count).
    complete: Mutex<HashMap<u64, usize>>,
}

impl Default for ShuffleManager {
    /// Budget-unlimited, memory-only manager (unit tests, toy jobs).
    fn default() -> Self {
        ShuffleManager::new(usize::MAX, None)
    }
}

impl ShuffleManager {
    /// A manager holding at most `budget` framed bytes in memory,
    /// spilling overflow to `disk` when present. Compression off,
    /// default fetch batching.
    pub fn new(budget: usize, disk: Option<Arc<DiskStore>>) -> Self {
        ShuffleManager::with_options(budget, disk, false, DEFAULT_FETCH_BATCH_BYTES)
    }

    /// Full-control constructor: `compress` turns on LZ bucket frames,
    /// `batch_bytes` bounds each `shuffle.fetch_multi` response frame.
    pub fn with_options(
        budget: usize,
        disk: Option<Arc<DiskStore>>,
        compress: bool,
        batch_bytes: usize,
    ) -> Self {
        ShuffleManager {
            buckets: RwLock::new(HashMap::new()),
            spilled: Mutex::new(HashMap::new()),
            sizes: Mutex::new(HashMap::new()),
            disk,
            budget,
            mem_used: AtomicUsize::new(0),
            clock: AtomicU64::new(0),
            compress,
            compress_gate: compress::AdaptiveGate::new(),
            batch_bytes: batch_bytes.max(1),
            net: RwLock::new(None),
            located: Mutex::new(HashMap::new()),
            done_maps: Mutex::new(HashMap::new()),
            complete: Mutex::new(HashMap::new()),
        }
    }

    /// Wire this manager into a cluster (worker startup).
    pub fn set_net(&self, net: Arc<dyn ShuffleNet>) {
        *self.net.write().unwrap() = Some(net);
    }

    fn net(&self) -> Option<Arc<dyn ShuffleNet>> {
        self.net.read().unwrap().clone()
    }

    /// Register map task `map_idx`'s bucket for reduce partition
    /// `reduce_idx`, encoding it through the `ser` codec. Idempotent:
    /// speculative duplicates overwrite with identical content.
    pub fn put_bucket<T: Encode>(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
        bucket: Vec<T>,
    ) {
        self.put_bucket_bytes(shuffle, map_idx, reduce_idx, to_bytes(&bucket));
    }

    /// Register an already-encoded bucket. The bytes are framed (and LZ
    /// compressed when `ignite.shuffle.compress` wins) before admission,
    /// so memory, spill and wire all carry the compact form. Admission
    /// under budget pressure **demotes the least-recently-used resident
    /// buckets** to the disk tier (`shuffle.evictions`) so recent buckets
    /// stay hot; only a bucket too large for the whole budget spills
    /// directly.
    pub fn put_bucket_bytes(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
        bytes: Vec<u8>,
    ) {
        let key = (shuffle, map_idx, reduce_idx);
        metrics::global().counter("shuffle.buckets.written").inc();
        metrics::global().counter("shuffle.bytes.written").add(bytes.len() as u64);
        let raw_framed_len = bytes.len() + 1;
        let framed = compress::frame_adaptive(&bytes, self.compress, &self.compress_gate);
        drop(bytes);
        if framed.first() == Some(&compress::FRAME_LZ) {
            metrics::global().counter("shuffle.bytes.compressed").add(framed.len() as u64);
            metrics::global()
                .counter("shuffle.bytes.saved")
                .add((raw_framed_len - framed.len()) as u64);
        }
        let size = framed.len();
        self.sizes
            .lock()
            .unwrap()
            .entry((shuffle, map_idx))
            .or_default()
            .insert(reduce_idx, size);

        // Budget admission happens under the buckets write lock so
        // concurrent map tasks cannot all observe a stale `mem_used` and
        // collectively blow past the budget, and a replaced duplicate
        // (speculative / recomputed put) is always subtracted exactly
        // once. Disk I/O (demotions, direct spills) runs after release.
        let overflow = {
            let mut buckets = self.buckets.write().unwrap();
            if let Some(old) = buckets.remove(&key) {
                self.mem_used.fetch_sub(old.bytes.len(), Ordering::Relaxed);
            }
            let used = self.mem_used.load(Ordering::Relaxed);
            let fits = used.checked_add(size).map(|total| total <= self.budget).unwrap_or(false);
            if fits || self.disk.is_none() {
                let used = self.insert_locked(&mut buckets, key, Arc::new(framed));
                metrics::global().gauge("shuffle.mem.used").set(used as i64);
                None
            } else {
                // Pick LRU victims whose combined size frees enough room.
                let need = (used + size).saturating_sub(self.budget);
                let mut order: Vec<(u64, BlockKey, usize)> = buckets
                    .iter()
                    .map(|(k, b)| (b.last_use.load(Ordering::Relaxed), *k, b.bytes.len()))
                    .collect();
                order.sort_unstable();
                let mut freed = 0usize;
                let mut victims: Vec<(BlockKey, Arc<Vec<u8>>)> = Vec::new();
                for (_, vkey, vlen) in order {
                    if freed >= need {
                        break;
                    }
                    freed += vlen;
                    victims.push((vkey, buckets.get(&vkey).unwrap().bytes.clone()));
                }
                if freed >= need {
                    // Insert now (briefly over budget); the demotions
                    // below bring usage back under it.
                    let used = self.insert_locked(&mut buckets, key, Arc::new(framed));
                    metrics::global().gauge("shuffle.mem.used").set(used as i64);
                    Some(Overflow::Demote(victims))
                } else {
                    Some(Overflow::SpillNew(framed))
                }
            }
        };
        match overflow {
            None => self.drop_stale_spill(&key),
            Some(Overflow::Demote(victims)) => {
                self.drop_stale_spill(&key);
                for (vkey, vbytes) in victims {
                    self.demote(vkey, vbytes);
                }
            }
            Some(Overflow::SpillNew(framed)) => {
                let disk = self.disk.as_ref().expect("spill path implies a disk tier");
                metrics::global().counter("shuffle.spills").inc();
                metrics::global().counter("shuffle.bytes.spilled").add(size as u64);
                crate::trace::event(
                    crate::trace::current(),
                    "event.spill",
                    &[
                        ("shuffle", shuffle.to_string()),
                        ("map", map_idx.to_string()),
                        ("reduce", reduce_idx.to_string()),
                        ("bytes", size.to_string()),
                    ],
                );
                if let Err(e) = disk.put_bytes(&block_id(shuffle, map_idx, reduce_idx), &framed) {
                    // Spill I/O failure: keep the bucket in memory (over
                    // budget beats losing data; lineage would recompute,
                    // but we still have the bytes in hand).
                    log::warn!(target: "shuffle", "spill of {key:?} failed ({e}); keeping in memory");
                    self.insert_mem(key, framed);
                    return;
                }
                self.spilled.lock().unwrap().insert(key, size);
            }
        }
    }

    /// Insert into the memory tier under an already-held write lock,
    /// stamping the LRU clock; returns the new `mem_used`.
    fn insert_locked(
        &self,
        buckets: &mut HashMap<BlockKey, MemBucket>,
        key: BlockKey,
        bytes: Arc<Vec<u8>>,
    ) -> usize {
        let size = bytes.len();
        let tick = self.clock.fetch_add(1, Ordering::Relaxed);
        if let Some(old) = buckets.insert(key, MemBucket { bytes, last_use: AtomicU64::new(tick) })
        {
            self.mem_used.fetch_sub(old.bytes.len(), Ordering::Relaxed);
        }
        self.mem_used.fetch_add(size, Ordering::Relaxed) + size
    }

    fn insert_mem(&self, key: BlockKey, bytes: Vec<u8>) {
        let mut buckets = self.buckets.write().unwrap();
        let used = self.insert_locked(&mut buckets, key, Arc::new(bytes));
        metrics::global().gauge("shuffle.mem.used").set(used as i64);
    }

    /// A bucket now lives in memory; drop any stale spilled copy a
    /// previous registration left on disk.
    fn drop_stale_spill(&self, key: &BlockKey) {
        if self.spilled.lock().unwrap().remove(key).is_some() {
            if let Some(disk) = &self.disk {
                disk.remove(&block_id(key.0, key.1, key.2));
            }
        }
    }

    /// Demote one resident bucket to the disk tier (LRU eviction). The
    /// disk copy is written and published in the `spilled` map BEFORE the
    /// memory copy is unlinked, so a concurrent reader always finds the
    /// bucket in some tier. If a recompute replaced the bucket since the
    /// victim was chosen, the newer resident copy wins and this demotion
    /// is rolled back; if a RACING demotion of the same bucket already
    /// unlinked it, its published disk copy (identical content — puts of
    /// one key are idempotent by contract) is left alone, so two
    /// admissions picking the same victim can never delete the bucket
    /// from every tier.
    fn demote(&self, key: BlockKey, bytes: Arc<Vec<u8>>) {
        enum Outcome {
            Demoted,
            Superseded,
            AlreadyGone,
        }
        let Some(disk) = &self.disk else { return };
        if let Err(e) = disk.put_bytes(&block_id(key.0, key.1, key.2), &bytes) {
            log::warn!(target: "shuffle", "demotion of {key:?} failed ({e}); keeping in memory");
            return;
        }
        self.spilled.lock().unwrap().insert(key, bytes.len());
        let outcome = {
            let mut buckets = self.buckets.write().unwrap();
            match buckets.get(&key) {
                Some(b) if Arc::ptr_eq(&b.bytes, &bytes) => {
                    buckets.remove(&key);
                    Outcome::Demoted
                }
                Some(_) => Outcome::Superseded,
                None => Outcome::AlreadyGone,
            }
        };
        match outcome {
            Outcome::Demoted => {
                let used = self.mem_used.fetch_sub(bytes.len(), Ordering::Relaxed) - bytes.len();
                metrics::global().gauge("shuffle.mem.used").set(used as i64);
                metrics::global().counter("shuffle.evictions").inc();
                metrics::global().counter("shuffle.bytes.spilled").add(bytes.len() as u64);
                crate::trace::event(
                    crate::trace::current(),
                    "event.evict",
                    &[
                        ("shuffle", key.0.to_string()),
                        ("map", key.1.to_string()),
                        ("reduce", key.2.to_string()),
                        ("bytes", bytes.len().to_string()),
                    ],
                );
            }
            Outcome::Superseded => {
                // A newer resident copy replaced this bucket mid-demotion:
                // the resident copy is authoritative — drop our disk copy
                // so the key is not double-present across tiers.
                if self.spilled.lock().unwrap().remove(&key).is_some() {
                    disk.remove(&block_id(key.0, key.1, key.2));
                }
            }
            Outcome::AlreadyGone => {
                // A racing demotion of this very bucket won: it did the
                // memory accounting and counted the eviction, and the
                // spilled entry + disk copy (ours or its — same bytes)
                // must stay, or the bucket would vanish from every tier.
            }
        }
    }

    /// Remove one bucket from every local tier, fixing accounting.
    fn drop_block(&self, key: &BlockKey) {
        if let Some(old) = self.buckets.write().unwrap().remove(key) {
            self.mem_used.fetch_sub(old.bytes.len(), Ordering::Relaxed);
        }
        if self.spilled.lock().unwrap().remove(key).is_some() {
            if let Some(disk) = &self.disk {
                disk.remove(&block_id(key.0, key.1, key.2));
            }
        }
        let mut sizes = self.sizes.lock().unwrap();
        if let Some(per_map) = sizes.get_mut(&(key.0, key.1)) {
            per_map.remove(&key.2);
            if per_map.is_empty() {
                sizes.remove(&(key.0, key.1));
            }
        }
    }

    /// Framed byte size of each of one map task's registered buckets, as
    /// `(reduce_idx, bytes)` pairs sorted by reduce index — what
    /// [`map_done`](Self::map_done) reports through the net so the master
    /// can place reduce tasks near their input bytes. O(reduces): reads
    /// the put-time size index, never scans the tiers.
    fn bucket_sizes_of(&self, shuffle: u64, map_idx: usize) -> Vec<(usize, usize)> {
        let mut sizes: Vec<(usize, usize)> = self
            .sizes
            .lock()
            .unwrap()
            .get(&(shuffle, map_idx))
            .map(|per_map| per_map.iter().map(|(r, s)| (*r, *s)).collect())
            .unwrap_or_default();
        sizes.sort_unstable();
        sizes
    }

    /// Mark map task finished (all its buckets registered). In cluster
    /// mode this first announces the output — with its per-reduce bucket
    /// sizes — to the master's map-output table so remote reduce tasks
    /// can find it (and the scheduler can place them near it); a failed
    /// registration fails the map task (the scheduler's retry re-runs
    /// it), keeping the invariant that a locally-complete map output is
    /// always locatable.
    pub fn map_done(&self, shuffle: u64, map_idx: usize, total_maps: usize) -> Result<()> {
        if let Some(net) = self.net() {
            let sizes = self.bucket_sizes_of(shuffle, map_idx);
            net.register(shuffle, map_idx, total_maps, &sizes).map_err(|e| {
                IgniteError::Storage(format!(
                    "map-output registration ({shuffle}, map {map_idx}) failed: {e}"
                ))
            })?;
        }
        let mut done = self.done_maps.lock().unwrap();
        let set = done.entry(shuffle).or_default();
        set.insert(map_idx);
        if set.len() == total_maps {
            self.complete.lock().unwrap().insert(shuffle, total_maps);
        }
        Ok(())
    }

    /// Is the map stage of `shuffle` fully materialized locally?
    pub fn is_complete(&self, shuffle: u64) -> bool {
        self.complete.lock().unwrap().contains_key(&shuffle)
    }

    /// Number of map outputs for a completed shuffle. Falls back to the
    /// cluster map-output table when the map stage ran on other workers.
    pub fn map_count(&self, shuffle: u64) -> Option<usize> {
        if let Some(n) = self.complete.lock().unwrap().get(&shuffle).copied() {
            return Some(n);
        }
        let outputs = self.locate(shuffle)?;
        if outputs.is_complete() {
            Some(outputs.total_maps)
        } else {
            None
        }
    }

    /// Cluster locate with per-shuffle caching; `None` without a net or
    /// when the master has no record.
    fn locate(&self, shuffle: u64) -> Option<MapOutputs> {
        if let Some(hit) = self.located.lock().unwrap().get(&shuffle) {
            if hit.is_complete() {
                return Some(hit.clone());
            }
        }
        let net = self.net()?;
        match net.locate(shuffle) {
            Ok(outputs) => {
                let mut cache = self.located.lock().unwrap();
                cache.insert(shuffle, outputs.clone());
                Some(outputs)
            }
            Err(e) => {
                log::debug!(target: "shuffle", "locate({shuffle}) failed: {e}");
                None
            }
        }
    }

    /// Fetch one bucket, decoded — the single-bucket read API. Resolution
    /// order: memory, disk (transparent read-back of spills), remote
    /// worker via `shuffle.fetch`. `Err` when missing everywhere
    /// (triggers stage recompute through lineage). Reduce tasks merging a
    /// whole shuffle should prefer
    /// [`fetch_reduce_bytes`](Self::fetch_reduce_bytes), which batches
    /// remote pulls per worker.
    pub fn fetch_bucket<T: Decode>(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
    ) -> Result<Vec<T>> {
        let framed = self.fetch_bucket_bytes(shuffle, map_idx, reduce_idx)?;
        decode_bucket(&framed)
    }

    /// Fetch one bucket's framed bytes through the tier chain.
    pub fn fetch_bucket_bytes(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
    ) -> Result<Arc<Vec<u8>>> {
        metrics::global().counter("shuffle.buckets.read").inc();
        if let Some(bytes) = self.local_bucket_bytes(shuffle, map_idx, reduce_idx) {
            return Ok(bytes);
        }
        // Remote tier.
        if let Some(net) = self.net() {
            if let Some(outputs) = self.locate(shuffle) {
                if let Some(addr) = outputs.addr_of(map_idx) {
                    if addr != net.local_addr() {
                        let t0 = std::time::Instant::now();
                        match net.fetch(addr, shuffle, map_idx, reduce_idx) {
                            Ok(bytes) => {
                                metrics::global().counter("shuffle.remote.fetches").inc();
                                metrics::global()
                                    .counter("shuffle.remote.bytes")
                                    .add(bytes.len() as u64);
                                metrics::global()
                                    .histogram("shuffle.fetch.latency")
                                    .record(t0.elapsed());
                                return Ok(Arc::new(bytes));
                            }
                            Err(e) => {
                                // The cached location may be stale (worker
                                // died, block recomputed elsewhere): drop
                                // it so the retry re-asks the master.
                                self.located.lock().unwrap().remove(&shuffle);
                                return Err(e);
                            }
                        }
                    }
                }
            }
        }
        Err(IgniteError::Storage(format!(
            "missing shuffle bucket ({shuffle}, map {map_idx}, reduce {reduce_idx})"
        )))
    }

    /// Fetch every map's bucket for reduce partition `reduce_idx`, framed,
    /// indexed by map — THE reduce-side read path. Local tiers resolve
    /// first; the remaining buckets are grouped by owning worker and
    /// pulled through [`ShuffleNet::fetch_multi`] in
    /// `ignite.shuffle.fetch.batch.bytes`-bounded frames, so remote
    /// round-trips are O(workers), not O(maps)
    /// (`shuffle.fetch.multi.{calls,buckets}`).
    pub fn fetch_reduce_bytes(
        &self,
        shuffle: u64,
        reduce_idx: usize,
        n_maps: usize,
    ) -> Result<Vec<Arc<Vec<u8>>>> {
        metrics::global().counter("shuffle.buckets.read").add(n_maps as u64);
        let mut out: Vec<Option<Arc<Vec<u8>>>> = (0..n_maps)
            .map(|m| self.local_bucket_bytes(shuffle, m, reduce_idx))
            .collect();
        let missing: Vec<usize> =
            out.iter().enumerate().filter(|(_, b)| b.is_none()).map(|(m, _)| m).collect();
        if !missing.is_empty() {
            let net = self.net().ok_or_else(|| {
                IgniteError::Storage(format!(
                    "missing shuffle buckets {missing:?} of ({shuffle}, reduce {reduce_idx})"
                ))
            })?;
            let outputs = self.locate(shuffle).ok_or_else(|| {
                IgniteError::Storage(format!("shuffle {shuffle} has no map-output locations"))
            })?;
            let local = net.local_addr();
            // Group missing maps by owning worker (one fetch_multi stream
            // per worker), preserving map order within each group.
            let mut groups: Vec<(String, Vec<usize>)> = Vec::new();
            for m in missing {
                let addr = outputs.addr_of(m).ok_or_else(|| {
                    IgniteError::Storage(format!("no location for map {m} of shuffle {shuffle}"))
                })?;
                if addr == local {
                    return Err(IgniteError::Storage(format!(
                        "bucket ({shuffle}, map {m}, reduce {reduce_idx}) missing locally"
                    )));
                }
                match groups.iter_mut().find(|g| g.0.as_str() == addr) {
                    Some((_, idxs)) => idxs.push(m),
                    None => groups.push((addr.to_string(), vec![m])),
                }
            }
            for (addr, mut idxs) in groups {
                while !idxs.is_empty() {
                    let t0 = std::time::Instant::now();
                    let got = match net.fetch_multi(
                        &addr,
                        shuffle,
                        reduce_idx,
                        &idxs,
                        self.batch_bytes,
                    ) {
                        Ok(got) => got,
                        Err(e) => {
                            // Stale location (worker died): drop the cache
                            // so the stage retry re-asks the master.
                            self.located.lock().unwrap().remove(&shuffle);
                            return Err(e);
                        }
                    };
                    metrics::global().counter("shuffle.remote.fetches").inc();
                    metrics::global().counter("shuffle.fetch.multi.calls").inc();
                    metrics::global().histogram("shuffle.fetch.latency").record(t0.elapsed());
                    let before = idxs.len();
                    for (m, bytes) in got {
                        match bytes {
                            Some(bytes) => {
                                metrics::global()
                                    .counter("shuffle.remote.bytes")
                                    .add(bytes.len() as u64);
                                metrics::global().counter("shuffle.fetch.multi.buckets").inc();
                                idxs.retain(|&x| x != m);
                                if m < out.len() {
                                    out[m] = Some(Arc::new(bytes));
                                }
                            }
                            None => {
                                self.located.lock().unwrap().remove(&shuffle);
                                return Err(IgniteError::Storage(format!(
                                    "worker {addr} no longer holds bucket \
                                     ({shuffle}, map {m}, reduce {reduce_idx})"
                                )));
                            }
                        }
                    }
                    if idxs.len() == before {
                        self.located.lock().unwrap().remove(&shuffle);
                        return Err(IgniteError::Storage(format!(
                            "fetch_multi from {addr} made no progress \
                             (shuffle {shuffle}, reduce {reduce_idx})"
                        )));
                    }
                }
            }
        }
        Ok(out
            .into_iter()
            .map(|b| b.expect("every bucket resolved above"))
            .collect())
    }

    /// Prefetch the framed bytes of many `(map, reduce)` buckets — the
    /// whole remote working set of a worker's task batch — into the
    /// local memory tier with ONE combined `shuffle.fetch_batch` stream
    /// per remote holder, so the batch's reduce tasks then merge from
    /// local reads instead of opening one `shuffle.fetch_multi` stream
    /// each. Best-effort by design: any error is swallowed (the
    /// per-task read path re-fetches and classifies failures), buckets
    /// already local are skipped, and over-budget buckets are dropped
    /// rather than demoting residents. Returns the number of buckets
    /// brought over.
    pub fn prefetch_pairs(&self, shuffle: u64, pairs: &[(usize, usize)]) -> usize {
        let Some(net) = self.net() else { return 0 };
        if pairs.is_empty() {
            return 0;
        }
        let Some(outputs) = self.locate(shuffle) else { return 0 };
        let local = net.local_addr();
        // Group the non-local misses by owning worker, preserving order.
        let mut groups: Vec<(String, Vec<(usize, usize)>)> = Vec::new();
        {
            let buckets = self.buckets.read().unwrap();
            let spilled = self.spilled.lock().unwrap();
            for &(m, r) in pairs {
                let key = (shuffle, m, r);
                if buckets.contains_key(&key) || spilled.contains_key(&key) {
                    continue;
                }
                let Some(addr) = outputs.addr_of(m) else { continue };
                if addr == local {
                    continue;
                }
                match groups.iter_mut().find(|g| g.0.as_str() == addr) {
                    Some((_, ps)) => ps.push((m, r)),
                    None => groups.push((addr.to_string(), vec![(m, r)])),
                }
            }
        }
        let mut fetched = 0usize;
        for (addr, mut ps) in groups {
            while !ps.is_empty() {
                let t0 = std::time::Instant::now();
                let got = match net.fetch_pairs(&addr, shuffle, &ps, self.batch_bytes) {
                    Ok(got) => got,
                    Err(e) => {
                        log::debug!(target: "shuffle", "prefetch from {addr} failed: {e}");
                        self.located.lock().unwrap().remove(&shuffle);
                        break;
                    }
                };
                metrics::global().counter("shuffle.remote.fetches").inc();
                metrics::global().counter("shuffle.fetch.batch.calls").inc();
                metrics::global().histogram("shuffle.fetch.latency").record(t0.elapsed());
                let before = ps.len();
                for ((m, r), bytes) in got {
                    ps.retain(|&p| p != (m, r));
                    if let Some(bytes) = bytes {
                        metrics::global()
                            .counter("shuffle.remote.bytes")
                            .add(bytes.len() as u64);
                        metrics::global().counter("shuffle.fetch.batch.buckets").inc();
                        fetched += 1;
                        self.insert_prefetched(shuffle, m, r, bytes);
                    }
                    // `None` (holder lost the bucket): leave it for the
                    // read path, which classifies the miss recoverable.
                }
                if ps.len() == before {
                    break;
                }
            }
        }
        fetched
    }

    /// Admit one remotely-prefetched, already-framed bucket into the
    /// memory tier. Never demotes residents or spills — the bytes remain
    /// fetchable from their owner, so an over-budget prefetch is simply
    /// dropped and the read path falls back to the streaming fetch.
    /// Deliberately does NOT touch the put-time size index: these are
    /// another worker's map outputs, and this worker must not report
    /// them as its own if it later runs that map task.
    fn insert_prefetched(&self, shuffle: u64, map_idx: usize, reduce_idx: usize, framed: Vec<u8>) {
        if self.mem_used.load(Ordering::Relaxed).saturating_add(framed.len()) > self.budget {
            metrics::global().counter("shuffle.prefetch.dropped").inc();
            return;
        }
        self.insert_mem((shuffle, map_idx, reduce_idx), framed);
    }

    /// Read a bucket's framed bytes from the local tiers only (memory,
    /// then disk), touching the LRU clock on a memory hit. This is what
    /// the worker's `shuffle.fetch` / `shuffle.fetch_multi` endpoints
    /// serve — remote requests must never recurse back into the remote
    /// tier, and the wire carries the framed (possibly compressed) form.
    pub fn local_bucket_bytes(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
    ) -> Option<Arc<Vec<u8>>> {
        let key = (shuffle, map_idx, reduce_idx);
        if let Some(b) = self.buckets.read().unwrap().get(&key) {
            b.last_use.store(self.clock.fetch_add(1, Ordering::Relaxed), Ordering::Relaxed);
            return Some(b.bytes.clone());
        }
        if self.spilled.lock().unwrap().contains_key(&key) {
            if let Some(disk) = &self.disk {
                if let Some(bytes) = disk.get_bytes(&block_id(shuffle, map_idx, reduce_idx)) {
                    metrics::global().counter("shuffle.spill.readbacks").inc();
                    return Some(Arc::new(bytes));
                }
            }
        }
        None
    }

    /// Drop a whole shuffle (fault injection: lose the map outputs, or
    /// normal cleanup after a job), from memory and disk.
    pub fn clear_shuffle(&self, shuffle: u64) {
        let keys: Vec<BlockKey> = self
            .buckets
            .read()
            .unwrap()
            .keys()
            .chain(self.spilled.lock().unwrap().keys())
            .filter(|(s, _, _)| *s == shuffle)
            .copied()
            .collect();
        for key in keys {
            self.drop_block(&key);
        }
        self.done_maps.lock().unwrap().remove(&shuffle);
        self.complete.lock().unwrap().remove(&shuffle);
        self.located.lock().unwrap().remove(&shuffle);
    }

    /// Drop a single map task's outputs (models losing one worker's local
    /// shuffle files), including spilled blocks — a lineage recompute
    /// re-registers them through the normal `put_bucket` path.
    pub fn lose_map_output(&self, shuffle: u64, map_idx: usize) {
        let keys: Vec<BlockKey> = self
            .buckets
            .read()
            .unwrap()
            .keys()
            .chain(self.spilled.lock().unwrap().keys())
            .filter(|(s, m, _)| *s == shuffle && *m == map_idx)
            .copied()
            .collect();
        for key in keys {
            self.drop_block(&key);
        }
        let mut done = self.done_maps.lock().unwrap();
        if let Some(set) = done.get_mut(&shuffle) {
            set.remove(&map_idx);
        }
        self.complete.lock().unwrap().remove(&shuffle);
    }

    /// Total buckets registered locally (both tiers).
    pub fn bucket_count(&self) -> usize {
        self.buckets.read().unwrap().len() + self.spilled.lock().unwrap().len()
    }

    /// Buckets currently on the disk tier.
    pub fn spilled_count(&self) -> usize {
        self.spilled.lock().unwrap().len()
    }

    /// Framed bytes currently held in memory.
    pub fn mem_used(&self) -> usize {
        self.mem_used.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn disk() -> Arc<DiskStore> {
        Arc::new(DiskStore::new("/tmp/mpignite-test-shuffle").unwrap())
    }

    fn counter(name: &str) -> u64 {
        metrics::global().counter(name).get()
    }

    #[test]
    fn partitioner_is_deterministic_and_in_range() {
        let p = HashPartitioner::new(7);
        for key in 0..1000u64 {
            let a = p.partition(&key);
            let b = p.partition(&key);
            assert_eq!(a, b);
            assert!(a < 7);
        }
    }

    #[test]
    fn partitioner_spreads_keys() {
        let p = HashPartitioner::new(4);
        let mut counts = [0usize; 4];
        for key in 0..1000u64 {
            counts[p.partition(&key)] += 1;
        }
        for c in counts {
            assert!(c > 150, "partition badly skewed: {counts:?}");
        }
    }

    #[test]
    fn stable_hasher_locked_by_test_vectors() {
        // These vectors pin the algorithm: if any of them changes, the
        // on-the-wire partition assignment changed — a breaking change
        // for mixed-version clusters. Recompute only deliberately.
        fn h<T: Hash>(v: T) -> u64 {
            let mut s = StableHasher::new();
            v.hash(&mut s);
            s.finish()
        }
        assert_eq!(h(0u64), h(0u64));
        assert_ne!(h(0u64), h(1u64));
        assert_ne!(h("a"), h("b"));
        assert_ne!(h(("ab", "c")), h(("a", "bc")), "length folding separates concatenations");
        // Same value hashed in two freshly-built hashers agrees (no
        // per-process randomness, unlike RandomState).
        let mut s1 = StableHasher::new();
        let mut s2 = StableHasher::new();
        "stability".hash(&mut s1);
        "stability".hash(&mut s2);
        assert_eq!(s1.finish(), s2.finish());
    }

    #[test]
    fn bucket_roundtrip_and_completion() {
        let sm = ShuffleManager::default();
        sm.put_bucket(1, 0, 0, vec![("a".to_string(), 1u64)]);
        sm.put_bucket(1, 0, 1, vec![("b".to_string(), 2u64)]);
        sm.map_done(1, 0, 2).unwrap();
        assert!(!sm.is_complete(1), "one of two maps done");
        sm.put_bucket(1, 1, 0, vec![("c".to_string(), 3u64)]);
        sm.put_bucket(1, 1, 1, Vec::<(String, u64)>::new());
        sm.map_done(1, 1, 2).unwrap();
        assert!(sm.is_complete(1));
        assert_eq!(sm.map_count(1), Some(2));

        let b: Vec<(String, u64)> = sm.fetch_bucket(1, 0, 1).unwrap();
        assert_eq!(b, vec![("b".to_string(), 2)]);
    }

    #[test]
    fn missing_bucket_is_an_error() {
        let sm = ShuffleManager::default();
        assert!(sm.fetch_bucket::<(u64, u64)>(9, 0, 0).is_err());
    }

    #[test]
    fn wrong_type_is_an_error() {
        let sm = ShuffleManager::default();
        sm.put_bucket(2, 0, 0, vec![1u64]);
        // Decoding u64 buckets as (String, u64) pairs must fail cleanly.
        assert!(sm.fetch_bucket::<(String, u64)>(2, 0, 0).is_err());
    }

    #[test]
    fn lose_map_output_invalidates_completion() {
        let sm = ShuffleManager::default();
        sm.put_bucket(3, 0, 0, vec![1u64]);
        sm.map_done(3, 0, 1).unwrap();
        assert!(sm.is_complete(3));
        sm.lose_map_output(3, 0);
        assert!(!sm.is_complete(3));
        assert!(sm.fetch_bucket::<u64>(3, 0, 0).is_err());
    }

    #[test]
    fn clear_shuffle_removes_only_that_shuffle() {
        let sm = ShuffleManager::default();
        sm.put_bucket(4, 0, 0, vec![1u64]);
        sm.put_bucket(5, 0, 0, vec![2u64]);
        sm.clear_shuffle(4);
        assert!(sm.fetch_bucket::<u64>(4, 0, 0).is_err());
        assert!(sm.fetch_bucket::<u64>(5, 0, 0).is_ok());
    }

    #[test]
    fn speculative_duplicate_put_is_idempotent() {
        let sm = ShuffleManager::default();
        sm.put_bucket(6, 0, 0, vec![1u64, 2]);
        let used_once = sm.mem_used();
        sm.put_bucket(6, 0, 0, vec![1u64, 2]); // same content, second attempt
        assert_eq!(sm.mem_used(), used_once, "duplicate put must not double-count");
        let b: Vec<u64> = sm.fetch_bucket(6, 0, 0).unwrap();
        assert_eq!(b, vec![1, 2]);
    }

    #[test]
    fn zero_budget_spills_everything_and_reads_back() {
        let sm = ShuffleManager::new(0, Some(disk()));
        sm.put_bucket(7, 0, 0, vec![(1u64, 10u64), (2, 20)]);
        sm.put_bucket(7, 0, 1, vec![(3u64, 30u64)]);
        assert_eq!(sm.spilled_count(), 2, "budget 0 spills every bucket");
        assert_eq!(sm.mem_used(), 0);
        let b: Vec<(u64, u64)> = sm.fetch_bucket(7, 0, 0).unwrap();
        assert_eq!(b, vec![(1, 10), (2, 20)]);
        let b: Vec<(u64, u64)> = sm.fetch_bucket(7, 0, 1).unwrap();
        assert_eq!(b, vec![(3, 30)]);
    }

    #[test]
    fn buckets_spill_past_budget_then_clear() {
        // ~each framed bucket is >8 bytes; a 64-byte budget keeps a few
        // resident and moves the rest to disk (demotion or direct spill).
        let sm = ShuffleManager::new(64, Some(disk()));
        for m in 0..16usize {
            sm.put_bucket(8, m, 0, vec![m as u64, 1, 2, 3]);
        }
        assert!(sm.spilled_count() > 0, "over-budget buckets must hit the disk tier");
        assert!(sm.mem_used() <= 64, "memory stays within budget");
        for m in 0..16usize {
            let b: Vec<u64> = sm.fetch_bucket(8, m, 0).unwrap();
            assert_eq!(b[0], m as u64, "spilled buckets read back");
        }
        sm.clear_shuffle(8);
        assert_eq!(sm.bucket_count(), 0);
        assert_eq!(sm.spilled_count(), 0);
        assert_eq!(sm.mem_used(), 0);
    }

    #[test]
    fn lru_demotes_cold_buckets_not_new_writes() {
        // Budget fits ~2 of 3 equal-size buckets. After touching A, a
        // third write must demote the cold B — not spill the new C.
        let payload = |tag: u64| vec![tag; 6]; // ~ >24 framed bytes each
        let one_size = {
            let probe = ShuffleManager::default();
            probe.put_bucket(1, 0, 0, payload(0));
            probe.mem_used()
        };
        let sm = ShuffleManager::new(one_size * 2, Some(disk()));
        sm.put_bucket(10, 0, 0, payload(1)); // A
        sm.put_bucket(10, 1, 0, payload(2)); // B
        assert_eq!(sm.spilled_count(), 0, "both fit");
        // Touch A so B becomes the LRU victim.
        assert_eq!(sm.fetch_bucket::<u64>(10, 0, 0).unwrap(), payload(1));
        let evictions_before = counter("shuffle.evictions");
        sm.put_bucket(10, 2, 0, payload(3)); // C demotes B
        assert_eq!(sm.spilled_count(), 1, "exactly one bucket demoted");
        assert!(counter("shuffle.evictions") > evictions_before);
        assert!(sm.mem_used() <= one_size * 2, "demotion restored the budget");
        // B reads back from disk; A and C still resident.
        let readbacks_before = counter("shuffle.spill.readbacks");
        assert_eq!(sm.fetch_bucket::<u64>(10, 1, 0).unwrap(), payload(2));
        assert!(counter("shuffle.spill.readbacks") > readbacks_before, "B was the victim");
        assert_eq!(sm.fetch_bucket::<u64>(10, 0, 0).unwrap(), payload(1));
        assert_eq!(sm.fetch_bucket::<u64>(10, 2, 0).unwrap(), payload(3));
    }

    #[test]
    fn oversized_bucket_spills_directly_even_after_demoting() {
        let sm = ShuffleManager::new(48, Some(disk()));
        sm.put_bucket(11, 0, 0, vec![1u64, 2]);
        // Far larger than the whole budget: demoting everything cannot
        // make room, so it must take the direct-spill path.
        sm.put_bucket(11, 1, 0, (0..64u64).collect::<Vec<u64>>());
        let b: Vec<u64> = sm.fetch_bucket(11, 1, 0).unwrap();
        assert_eq!(b.len(), 64);
        assert!(sm.spilled_count() >= 1);
        assert!(sm.mem_used() <= 48);
    }

    #[test]
    fn compression_shrinks_storage_and_round_trips() {
        let rows: Vec<String> =
            (0..64).map(|i| format!("key-{:03}-padding-padding-padding", i % 4)).collect();
        let raw = ShuffleManager::default();
        raw.put_bucket(12, 0, 0, rows.clone());
        let raw_size = raw.mem_used();

        let saved_before = counter("shuffle.bytes.saved");
        let lz = ShuffleManager::with_options(usize::MAX, None, true, DEFAULT_FETCH_BATCH_BYTES);
        lz.put_bucket(12, 0, 0, rows.clone());
        assert!(
            lz.mem_used() * 2 < raw_size,
            "repetitive keys must compress ({} vs {raw_size})",
            lz.mem_used()
        );
        assert!(counter("shuffle.bytes.saved") > saved_before);
        let back: Vec<String> = lz.fetch_bucket(12, 0, 0).unwrap();
        assert_eq!(back, rows, "compressed bucket decodes bit-identically");
    }

    #[test]
    fn compressed_spill_and_readback() {
        let rows: Vec<String> = (0..64).map(|i| format!("value-{:02}-padding", i % 8)).collect();
        let sm = ShuffleManager::with_options(0, Some(disk()), true, DEFAULT_FETCH_BATCH_BYTES);
        sm.put_bucket(13, 0, 0, rows.clone());
        assert_eq!(sm.spilled_count(), 1);
        let back: Vec<String> = sm.fetch_bucket(13, 0, 0).unwrap();
        assert_eq!(back, rows);
    }

    #[test]
    fn lose_map_output_drops_spilled_blocks_too() {
        let sm = ShuffleManager::new(0, Some(disk()));
        sm.put_bucket(9, 0, 0, vec![1u64]);
        sm.map_done(9, 0, 1).unwrap();
        assert_eq!(sm.spilled_count(), 1);
        sm.lose_map_output(9, 0);
        assert_eq!(sm.spilled_count(), 0);
        assert!(sm.fetch_bucket::<u64>(9, 0, 0).is_err());
        // Recompute path: re-register and read back.
        sm.put_bucket(9, 0, 0, vec![1u64]);
        sm.map_done(9, 0, 1).unwrap();
        assert!(sm.is_complete(9));
        assert_eq!(sm.fetch_bucket::<u64>(9, 0, 0).unwrap(), vec![1]);
    }

    struct OneBucketNet {
        bytes: Vec<u8>,
        fetches: AtomicUsize,
    }

    impl ShuffleNet for OneBucketNet {
        fn register(&self, _s: u64, _m: usize, _t: usize, _b: &[(usize, usize)]) -> Result<()> {
            Ok(())
        }

        fn locate(&self, _s: u64) -> Result<MapOutputs> {
            Ok(MapOutputs {
                total_maps: 1,
                locations: HashMap::from([(0, "peer:1".to_string())]),
            })
        }

        fn fetch(&self, addr: &str, _s: u64, _m: usize, _r: usize) -> Result<Vec<u8>> {
            assert_eq!(addr, "peer:1");
            self.fetches.fetch_add(1, Ordering::SeqCst);
            Ok(self.bytes.clone())
        }

        fn local_addr(&self) -> String {
            "self:0".to_string()
        }
    }

    #[test]
    fn remote_tier_fetches_missing_buckets() {
        let sm = ShuffleManager::default();
        let net = Arc::new(OneBucketNet {
            // The wire always carries framed bytes (what the serving
            // worker's local_bucket_bytes returns).
            bytes: compress::frame(&to_bytes(&vec![(7u64, 70u64)]), false),
            fetches: AtomicUsize::new(0),
        });
        sm.set_net(net.clone());
        // Not present locally in any tier → pulled over the net hook.
        let b: Vec<(u64, u64)> = sm.fetch_bucket(11, 0, 0).unwrap();
        assert_eq!(b, vec![(7, 70)]);
        assert_eq!(net.fetches.load(Ordering::SeqCst), 1);
        // map_count resolves through locate() for remote-only shuffles.
        assert_eq!(sm.map_count(11), Some(1));
    }

    /// A net that streams at most one bucket per `fetch_multi` frame —
    /// the smallest legal response — to exercise the client's re-ask loop.
    struct OnePerFrameNet {
        buckets: HashMap<usize, Vec<u8>>, // map_idx → framed bytes
        total_maps: usize,
        calls: AtomicUsize,
    }

    impl ShuffleNet for OnePerFrameNet {
        fn register(&self, _s: u64, _m: usize, _t: usize, _b: &[(usize, usize)]) -> Result<()> {
            Ok(())
        }

        fn locate(&self, _s: u64) -> Result<MapOutputs> {
            Ok(MapOutputs {
                total_maps: self.total_maps,
                locations: (0..self.total_maps).map(|m| (m, "peer:1".to_string())).collect(),
            })
        }

        fn fetch(&self, _a: &str, _s: u64, m: usize, _r: usize) -> Result<Vec<u8>> {
            self.buckets
                .get(&m)
                .cloned()
                .ok_or_else(|| IgniteError::Storage("no bucket".into()))
        }

        fn fetch_multi(
            &self,
            _addr: &str,
            _shuffle: u64,
            _reduce_idx: usize,
            map_idxs: &[usize],
            _batch_bytes: usize,
        ) -> Result<Vec<(usize, Option<Vec<u8>>)>> {
            self.calls.fetch_add(1, Ordering::SeqCst);
            let m = map_idxs[0];
            Ok(vec![(m, self.buckets.get(&m).cloned())])
        }

        fn local_addr(&self) -> String {
            "self:0".to_string()
        }
    }

    #[test]
    fn fetch_reduce_streams_frames_until_all_buckets_arrive() {
        let sm = ShuffleManager::default();
        sm.put_bucket(14, 1, 0, vec![100u64]); // map 1 is already local
        let net = Arc::new(OnePerFrameNet {
            buckets: (0..4usize)
                .filter(|&m| m != 1)
                .map(|m| (m, compress::frame(&to_bytes(&vec![m as u64]), false)))
                .collect(),
            total_maps: 4,
            calls: AtomicUsize::new(0),
        });
        sm.set_net(net.clone());
        let multi_before = counter("shuffle.fetch.multi.buckets");
        let framed = sm.fetch_reduce_bytes(14, 0, 4).unwrap();
        assert_eq!(framed.len(), 4);
        for (m, f) in framed.iter().enumerate() {
            let rows: Vec<u64> = decode_bucket(f).unwrap();
            let want = if m == 1 { 100 } else { m as u64 };
            assert_eq!(rows, vec![want], "map {m}");
        }
        // One frame per missing bucket with this tiny-frame net: the
        // client kept re-asking until the stream drained.
        assert_eq!(net.calls.load(Ordering::SeqCst), 3);
        assert_eq!(counter("shuffle.fetch.multi.buckets") - multi_before, 3);
    }

    #[test]
    fn fetch_reduce_missing_everywhere_is_an_error() {
        let sm = ShuffleManager::default();
        assert!(sm.fetch_reduce_bytes(15, 0, 2).is_err());
    }

    /// A net that records `fetch_pairs` streams — the cross-task batch
    /// path — and serves every pair from one table.
    struct PairNet {
        buckets: HashMap<(usize, usize), Vec<u8>>,
        total_maps: usize,
        pair_calls: AtomicUsize,
    }

    impl ShuffleNet for PairNet {
        fn register(&self, _s: u64, _m: usize, _t: usize, _b: &[(usize, usize)]) -> Result<()> {
            Ok(())
        }

        fn locate(&self, _s: u64) -> Result<MapOutputs> {
            Ok(MapOutputs {
                total_maps: self.total_maps,
                locations: (0..self.total_maps).map(|m| (m, "peer:1".to_string())).collect(),
            })
        }

        fn fetch(&self, _a: &str, _s: u64, m: usize, r: usize) -> Result<Vec<u8>> {
            self.buckets
                .get(&(m, r))
                .cloned()
                .ok_or_else(|| IgniteError::Storage("no bucket".into()))
        }

        fn fetch_pairs(
            &self,
            _addr: &str,
            _shuffle: u64,
            pairs: &[(usize, usize)],
            _batch_bytes: usize,
        ) -> Result<Vec<((usize, usize), Option<Vec<u8>>)>> {
            self.pair_calls.fetch_add(1, Ordering::SeqCst);
            Ok(pairs.iter().map(|&p| (p, self.buckets.get(&p).cloned())).collect())
        }

        fn local_addr(&self) -> String {
            "self:0".to_string()
        }
    }

    #[test]
    fn prefetch_pairs_pulls_a_task_batch_in_one_stream() {
        let sm = ShuffleManager::default();
        sm.put_bucket(16, 0, 0, vec![900u64]); // already local: skipped
        let net = Arc::new(PairNet {
            buckets: (0..2usize)
                .flat_map(|m| {
                    (0..3usize).map(move |r| {
                        ((m, r), compress::frame(&to_bytes(&vec![(m * 10 + r) as u64]), false))
                    })
                })
                .collect(),
            total_maps: 2,
            pair_calls: AtomicUsize::new(0),
        });
        sm.set_net(net.clone());
        // A 3-reduce task batch over 2 maps: 6 buckets, 1 already local,
        // 5 fetched — through ONE stream to the single remote holder.
        let pairs: Vec<(usize, usize)> =
            (0..2).flat_map(|m| (0..3).map(move |r| (m, r))).collect();
        let fetched = sm.prefetch_pairs(16, &pairs);
        assert_eq!(fetched, 5);
        assert_eq!(net.pair_calls.load(Ordering::SeqCst), 1);
        // The batch's reduce reads now resolve locally: no fetch_multi
        // stream (which PairNet would route through per-bucket `fetch`).
        for r in 0..3usize {
            let framed = sm.fetch_reduce_bytes(16, r, 2).unwrap();
            assert_eq!(framed.len(), 2);
        }
        // Re-prefetching is a no-op (everything already local).
        assert_eq!(sm.prefetch_pairs(16, &pairs), 0);
        assert_eq!(net.pair_calls.load(Ordering::SeqCst), 1);
    }
}
