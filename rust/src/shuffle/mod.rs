//! Shuffle manager — materializes the stage boundaries the DAG scheduler
//! cuts ("a stage boundary is determined by when data needs to be shuffled
//! through the cluster", paper §2.2).
//!
//! Map tasks partition their output by key hash into `reduce`-side buckets
//! registered here; reduce tasks fetch every map task's bucket for their
//! partition. Buckets are typed (`Arc<dyn Any>`), kept in memory, and the
//! manager tracks per-shuffle completion so a finished map stage is never
//! re-run (and can be, if a fault wipes it — lineage recomputation).

use crate::error::{IgniteError, Result};
use crate::metrics;
use std::any::Any;
use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::sync::{Mutex, RwLock};

/// Deterministic hash partitioner (Spark's default).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HashPartitioner {
    pub partitions: usize,
}

impl HashPartitioner {
    pub fn new(partitions: usize) -> Self {
        assert!(partitions > 0, "need at least one partition");
        HashPartitioner { partitions }
    }

    pub fn partition<K: Hash>(&self, key: &K) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() % self.partitions as u64) as usize
    }
}

type Bucket = std::sync::Arc<dyn Any + Send + Sync>;

/// In-memory shuffle block registry.
#[derive(Default)]
pub struct ShuffleManager {
    buckets: RwLock<HashMap<(u64, usize, usize), Bucket>>,
    /// Completed map tasks per shuffle.
    done_maps: Mutex<HashMap<u64, HashSet<usize>>>,
    /// Shuffles whose map stage has fully completed (with map count).
    complete: Mutex<HashMap<u64, usize>>,
}

impl ShuffleManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register map task `map_idx`'s bucket for reduce partition
    /// `reduce_idx`. Idempotent: speculative duplicates overwrite with
    /// identical content.
    pub fn put_bucket<T: Send + Sync + 'static>(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
        bucket: Vec<T>,
    ) {
        metrics::global().counter("shuffle.buckets.written").inc();
        self.buckets
            .write()
            .unwrap()
            .insert((shuffle, map_idx, reduce_idx), std::sync::Arc::new(bucket));
    }

    /// Mark map task finished (all its buckets registered).
    pub fn map_done(&self, shuffle: u64, map_idx: usize, total_maps: usize) {
        let mut done = self.done_maps.lock().unwrap();
        let set = done.entry(shuffle).or_default();
        set.insert(map_idx);
        if set.len() == total_maps {
            self.complete.lock().unwrap().insert(shuffle, total_maps);
        }
    }

    /// Is the map stage of `shuffle` fully materialized?
    pub fn is_complete(&self, shuffle: u64) -> bool {
        self.complete.lock().unwrap().contains_key(&shuffle)
    }

    /// Number of map outputs for a completed shuffle.
    pub fn map_count(&self, shuffle: u64) -> Option<usize> {
        self.complete.lock().unwrap().get(&shuffle).copied()
    }

    /// Fetch one bucket; `Err` when missing (triggers stage recompute).
    pub fn get_bucket<T: Send + Sync + 'static>(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
    ) -> Result<std::sync::Arc<Vec<T>>> {
        metrics::global().counter("shuffle.buckets.read").inc();
        let guard = self.buckets.read().unwrap();
        let bucket = guard.get(&(shuffle, map_idx, reduce_idx)).cloned().ok_or_else(|| {
            IgniteError::Storage(format!(
                "missing shuffle bucket ({shuffle}, map {map_idx}, reduce {reduce_idx})"
            ))
        })?;
        bucket.downcast::<Vec<T>>().map_err(|_| {
            IgniteError::Storage(format!("shuffle bucket ({shuffle}, {map_idx}, {reduce_idx}) has wrong type"))
        })
    }

    /// Drop a whole shuffle (fault injection: lose the map outputs, or
    /// normal cleanup after a job).
    pub fn clear_shuffle(&self, shuffle: u64) {
        self.buckets.write().unwrap().retain(|(s, _, _), _| *s != shuffle);
        self.done_maps.lock().unwrap().remove(&shuffle);
        self.complete.lock().unwrap().remove(&shuffle);
    }

    /// Drop a single map task's outputs (models losing one worker's local
    /// shuffle files).
    pub fn lose_map_output(&self, shuffle: u64, map_idx: usize) {
        self.buckets
            .write()
            .unwrap()
            .retain(|(s, m, _), _| !(*s == shuffle && *m == map_idx));
        let mut done = self.done_maps.lock().unwrap();
        if let Some(set) = done.get_mut(&shuffle) {
            set.remove(&map_idx);
        }
        self.complete.lock().unwrap().remove(&shuffle);
    }

    pub fn bucket_count(&self) -> usize {
        self.buckets.read().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partitioner_is_deterministic_and_in_range() {
        let p = HashPartitioner::new(7);
        for key in 0..1000u64 {
            let a = p.partition(&key);
            let b = p.partition(&key);
            assert_eq!(a, b);
            assert!(a < 7);
        }
    }

    #[test]
    fn partitioner_spreads_keys() {
        let p = HashPartitioner::new(4);
        let mut counts = [0usize; 4];
        for key in 0..1000u64 {
            counts[p.partition(&key)] += 1;
        }
        for c in counts {
            assert!(c > 150, "partition badly skewed: {counts:?}");
        }
    }

    #[test]
    fn bucket_roundtrip_and_completion() {
        let sm = ShuffleManager::new();
        sm.put_bucket(1, 0, 0, vec![("a".to_string(), 1u64)]);
        sm.put_bucket(1, 0, 1, vec![("b".to_string(), 2u64)]);
        sm.map_done(1, 0, 2);
        assert!(!sm.is_complete(1), "one of two maps done");
        sm.put_bucket(1, 1, 0, vec![("c".to_string(), 3u64)]);
        sm.put_bucket(1, 1, 1, Vec::<(String, u64)>::new());
        sm.map_done(1, 1, 2);
        assert!(sm.is_complete(1));
        assert_eq!(sm.map_count(1), Some(2));

        let b = sm.get_bucket::<(String, u64)>(1, 0, 1).unwrap();
        assert_eq!(*b, vec![("b".to_string(), 2)]);
    }

    #[test]
    fn missing_bucket_is_an_error() {
        let sm = ShuffleManager::new();
        assert!(sm.get_bucket::<(u64, u64)>(9, 0, 0).is_err());
    }

    #[test]
    fn wrong_type_is_an_error() {
        let sm = ShuffleManager::new();
        sm.put_bucket(2, 0, 0, vec![1u64]);
        assert!(sm.get_bucket::<(String, u64)>(2, 0, 0).is_err());
    }

    #[test]
    fn lose_map_output_invalidates_completion() {
        let sm = ShuffleManager::new();
        sm.put_bucket(3, 0, 0, vec![1u64]);
        sm.map_done(3, 0, 1);
        assert!(sm.is_complete(3));
        sm.lose_map_output(3, 0);
        assert!(!sm.is_complete(3));
        assert!(sm.get_bucket::<u64>(3, 0, 0).is_err());
    }

    #[test]
    fn clear_shuffle_removes_only_that_shuffle() {
        let sm = ShuffleManager::new();
        sm.put_bucket(4, 0, 0, vec![1u64]);
        sm.put_bucket(5, 0, 0, vec![2u64]);
        sm.clear_shuffle(4);
        assert!(sm.get_bucket::<u64>(4, 0, 0).is_err());
        assert!(sm.get_bucket::<u64>(5, 0, 0).is_ok());
    }

    #[test]
    fn speculative_duplicate_put_is_idempotent() {
        let sm = ShuffleManager::new();
        sm.put_bucket(6, 0, 0, vec![1u64, 2]);
        sm.put_bucket(6, 0, 0, vec![1u64, 2]); // same content, second attempt
        let b = sm.get_bucket::<u64>(6, 0, 0).unwrap();
        assert_eq!(*b, vec![1, 2]);
    }
}
