//! Shuffle manager — materializes the stage boundaries the DAG scheduler
//! cuts ("a stage boundary is determined by when data needs to be shuffled
//! through the cluster", paper §2.2).
//!
//! Map tasks partition their output by key hash into `reduce`-side buckets
//! registered here. Since PR 1 the pipeline is **byte-oriented and
//! tiered**: buckets are encoded through the [`crate::ser`] codec at
//! registration, held in memory while a per-shuffle byte budget
//! (`ignite.shuffle.memory.bytes`) allows, **spilled** to the engine's
//! [`crate::storage::DiskStore`] past the budget, and — when the manager
//! is wired to a cluster via [`ShuffleNet`] — **fetched from remote
//! workers** over the `shuffle.fetch` RPC endpoint. Reduce tasks see one
//! API, [`ShuffleManager::fetch_bucket`], regardless of where the bytes
//! live (memory → disk → remote).
//!
//! The manager tracks per-shuffle completion so a finished map stage is
//! never re-run (and can be, if a fault wipes it — lineage recomputation
//! re-encodes and re-registers the buckets, including spilled ones).

use crate::error::{IgniteError, Result};
use crate::metrics;
use crate::ser::{from_bytes, to_bytes, Decode, Encode};
use crate::storage::DiskStore;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

// ------------------------------------------------------------ hashing --

/// Fixed seed for [`StableHasher`]. Never change this value: partition
/// assignment must agree across processes and releases, because a reduce
/// task on worker B fetches the bucket a map task on worker A wrote for
/// it — both sides must compute the same `partition(key)`.
const STABLE_SEED: u64 = 0x51_7C_C1_B7_27_22_0A_95;

/// FxHash-style 64-bit hasher with a **fixed, documented seed**.
///
/// `std::collections::hash_map::DefaultHasher` (SipHash-1-3) does not
/// guarantee a stable algorithm across Rust releases, so hashing a key in
/// two different binaries may disagree — fatal for cross-process shuffle.
/// This hasher is the classic Fx multiply-rotate-xor mix (as used by
/// rustc's FxHasher), fixed here byte-for-byte: state' =
/// `(rotl5(state) ^ word) * K` with `K = 0x51_7C_C1_B7_27_22_0A_95`,
/// words consumed as little-endian u64 chunks with a zero-padded tail.
/// Stability is locked by test vectors below.
#[derive(Debug, Clone)]
pub struct StableHasher {
    state: u64,
}

impl Default for StableHasher {
    fn default() -> Self {
        StableHasher::new()
    }
}

impl StableHasher {
    pub fn new() -> Self {
        StableHasher { state: STABLE_SEED }
    }

    #[inline]
    fn mix(&mut self, word: u64) {
        self.state = (self.state.rotate_left(5) ^ word).wrapping_mul(STABLE_SEED);
    }
}

impl Hasher for StableHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for chunk in &mut chunks {
            self.mix(u64::from_le_bytes(chunk.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut tail = [0u8; 8];
            tail[..rem.len()].copy_from_slice(rem);
            self.mix(u64::from_le_bytes(tail));
        }
        // Fold the length in so "ab"+"c" != "a"+"bc" across write calls
        // of prefix-sharing keys.
        self.mix(bytes.len() as u64);
    }

    // The default integer methods forward through native-endian bytes;
    // pin them to little-endian so big- and little-endian workers in one
    // cluster agree on partition assignment.
    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.write(&[n]);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.write(&n.to_le_bytes());
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.write(&n.to_le_bytes());
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.write(&n.to_le_bytes());
    }

    #[inline]
    fn write_u128(&mut self, n: u128) {
        self.write(&n.to_le_bytes());
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.write(&(n as u64).to_le_bytes());
    }

    #[inline]
    fn write_i8(&mut self, n: i8) {
        self.write_u8(n as u8);
    }

    #[inline]
    fn write_i16(&mut self, n: i16) {
        self.write_u16(n as u16);
    }

    #[inline]
    fn write_i32(&mut self, n: i32) {
        self.write_u32(n as u32);
    }

    #[inline]
    fn write_i64(&mut self, n: i64) {
        self.write_u64(n as u64);
    }

    #[inline]
    fn write_i128(&mut self, n: i128) {
        self.write_u128(n as u128);
    }

    #[inline]
    fn write_isize(&mut self, n: isize) {
        self.write_usize(n as usize);
    }
}

/// Deterministic hash partitioner (Spark's default), stable across
/// processes (see [`StableHasher`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HashPartitioner {
    pub partitions: usize,
}

impl HashPartitioner {
    pub fn new(partitions: usize) -> Self {
        assert!(partitions > 0, "need at least one partition");
        HashPartitioner { partitions }
    }

    pub fn partition<K: Hash>(&self, key: &K) -> usize {
        let mut h = StableHasher::new();
        key.hash(&mut h);
        (h.finish() % self.partitions as u64) as usize
    }
}

// ------------------------------------------------------- remote plane --

/// Locations of a shuffle's completed map outputs, as tracked by the
/// cluster master: map index → worker RPC address.
#[derive(Debug, Clone, Default)]
pub struct MapOutputs {
    pub total_maps: usize,
    pub locations: HashMap<usize, String>,
}

impl MapOutputs {
    /// All `total_maps` outputs are registered somewhere.
    pub fn is_complete(&self) -> bool {
        self.total_maps > 0 && self.locations.len() >= self.total_maps
    }

    pub fn addr_of(&self, map_idx: usize) -> Option<&str> {
        self.locations.get(&map_idx).map(String::as_str)
    }
}

/// Network hooks wiring a [`ShuffleManager`] into a cluster: registration
/// of completed map outputs with the master's map-output table, lookup of
/// bucket locations, and the `shuffle.fetch` pull itself. Implemented
/// over RPC in [`crate::cluster`]; absent in pure local mode.
pub trait ShuffleNet: Send + Sync {
    /// Announce that this process holds map output `map_idx` of `shuffle`.
    fn register(&self, shuffle: u64, map_idx: usize, total_maps: usize) -> Result<()>;
    /// Ask the master where every map output of `shuffle` lives.
    fn locate(&self, shuffle: u64) -> Result<MapOutputs>;
    /// Fetch one bucket's encoded bytes from the worker at `addr`.
    fn fetch(&self, addr: &str, shuffle: u64, map_idx: usize, reduce_idx: usize) -> Result<Vec<u8>>;
    /// This process's own shuffle-serving address (skip self-fetch).
    fn local_addr(&self) -> String;
}

// ------------------------------------------------------------ manager --

type BlockKey = (u64, usize, usize);

fn block_id(shuffle: u64, map_idx: usize, reduce_idx: usize) -> String {
    format!("shuffle-{shuffle}-{map_idx}-{reduce_idx}")
}

/// Byte-oriented, tiered shuffle block registry (memory → disk → remote).
pub struct ShuffleManager {
    /// In-memory tier: encoded buckets within the byte budget.
    buckets: RwLock<HashMap<BlockKey, Arc<Vec<u8>>>>,
    /// Keys currently spilled to `disk` (bytes live in the DiskStore).
    spilled: Mutex<HashSet<BlockKey>>,
    /// Spill tier; `None` in budget-unlimited unit-test setups.
    disk: Option<Arc<DiskStore>>,
    /// In-memory byte budget across all shuffles.
    budget: usize,
    mem_used: AtomicUsize,
    /// Cluster plane; `None` in local mode.
    net: RwLock<Option<Arc<dyn ShuffleNet>>>,
    /// Cached master locate() answers (one RPC per shuffle, not per bucket).
    located: Mutex<HashMap<u64, MapOutputs>>,
    /// Completed map tasks per shuffle.
    done_maps: Mutex<HashMap<u64, HashSet<usize>>>,
    /// Shuffles whose map stage has fully completed locally (with map count).
    complete: Mutex<HashMap<u64, usize>>,
}

impl Default for ShuffleManager {
    /// Budget-unlimited, memory-only manager (unit tests, toy jobs).
    fn default() -> Self {
        ShuffleManager::new(usize::MAX, None)
    }
}

impl ShuffleManager {
    /// A manager holding at most `budget` encoded bytes in memory,
    /// spilling overflow to `disk` when present.
    pub fn new(budget: usize, disk: Option<Arc<DiskStore>>) -> Self {
        ShuffleManager {
            buckets: RwLock::new(HashMap::new()),
            spilled: Mutex::new(HashSet::new()),
            disk,
            budget,
            mem_used: AtomicUsize::new(0),
            net: RwLock::new(None),
            located: Mutex::new(HashMap::new()),
            done_maps: Mutex::new(HashMap::new()),
            complete: Mutex::new(HashMap::new()),
        }
    }

    /// Wire this manager into a cluster (worker startup).
    pub fn set_net(&self, net: Arc<dyn ShuffleNet>) {
        *self.net.write().unwrap() = Some(net);
    }

    fn net(&self) -> Option<Arc<dyn ShuffleNet>> {
        self.net.read().unwrap().clone()
    }

    /// Register map task `map_idx`'s bucket for reduce partition
    /// `reduce_idx`, encoding it through the `ser` codec. Idempotent:
    /// speculative duplicates overwrite with identical content.
    pub fn put_bucket<T: Encode>(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
        bucket: Vec<T>,
    ) {
        self.put_bucket_bytes(shuffle, map_idx, reduce_idx, to_bytes(&bucket));
    }

    /// Register an already-encoded bucket. Over-budget buckets spill to
    /// the disk tier (counted in `shuffle.spills` / `shuffle.bytes.spilled`).
    pub fn put_bucket_bytes(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
        bytes: Vec<u8>,
    ) {
        let key = (shuffle, map_idx, reduce_idx);
        let size = bytes.len();
        metrics::global().counter("shuffle.buckets.written").inc();
        metrics::global().counter("shuffle.bytes.written").add(size as u64);

        // Budget admission happens under the buckets write lock so
        // concurrent map tasks cannot all observe a stale `mem_used` and
        // collectively blow past the budget, and a replaced duplicate
        // (speculative / recomputed put) is always subtracted exactly once.
        let to_spill = {
            let mut buckets = self.buckets.write().unwrap();
            if let Some(old) = buckets.remove(&key) {
                self.mem_used.fetch_sub(old.len(), Ordering::Relaxed);
            }
            let fits = self
                .mem_used
                .load(Ordering::Relaxed)
                .checked_add(size)
                .map(|total| total <= self.budget)
                .unwrap_or(false);
            if self.disk.is_some() && !fits {
                Some(bytes)
            } else {
                buckets.insert(key, Arc::new(bytes));
                let used = self.mem_used.fetch_add(size, Ordering::Relaxed) + size;
                metrics::global().gauge("shuffle.mem.used").set(used as i64);
                None
            }
        };
        match to_spill {
            Some(bytes) => {
                let disk = self.disk.as_ref().expect("spill path implies a disk tier");
                metrics::global().counter("shuffle.spills").inc();
                metrics::global().counter("shuffle.bytes.spilled").add(size as u64);
                if let Err(e) = disk.put_bytes(&block_id(shuffle, map_idx, reduce_idx), &bytes) {
                    // Spill I/O failure: keep the bucket in memory (over
                    // budget beats losing data; lineage would recompute,
                    // but we still have the bytes in hand).
                    log::warn!(target: "shuffle", "spill of {key:?} failed ({e}); keeping in memory");
                    self.insert_mem(key, bytes);
                    return;
                }
                self.spilled.lock().unwrap().insert(key);
            }
            None => {
                // The bucket now lives in memory; drop any stale spilled
                // copy a previous registration left on disk.
                if self.spilled.lock().unwrap().remove(&key) {
                    if let Some(disk) = &self.disk {
                        disk.remove(&block_id(shuffle, map_idx, reduce_idx));
                    }
                }
            }
        }
    }

    fn insert_mem(&self, key: BlockKey, bytes: Vec<u8>) {
        let size = bytes.len();
        let mut buckets = self.buckets.write().unwrap();
        if let Some(old) = buckets.insert(key, Arc::new(bytes)) {
            self.mem_used.fetch_sub(old.len(), Ordering::Relaxed);
        }
        let used = self.mem_used.fetch_add(size, Ordering::Relaxed) + size;
        metrics::global().gauge("shuffle.mem.used").set(used as i64);
    }

    /// Remove one bucket from every local tier, fixing accounting.
    fn drop_block(&self, key: &BlockKey) {
        if let Some(old) = self.buckets.write().unwrap().remove(key) {
            self.mem_used.fetch_sub(old.len(), Ordering::Relaxed);
        }
        if self.spilled.lock().unwrap().remove(key) {
            if let Some(disk) = &self.disk {
                disk.remove(&block_id(key.0, key.1, key.2));
            }
        }
    }

    /// Mark map task finished (all its buckets registered). In cluster
    /// mode this first announces the output to the master's map-output
    /// table so remote reduce tasks can find it; a failed registration
    /// fails the map task (the scheduler's retry re-runs it), keeping the
    /// invariant that a locally-complete map output is always locatable.
    pub fn map_done(&self, shuffle: u64, map_idx: usize, total_maps: usize) -> Result<()> {
        if let Some(net) = self.net() {
            net.register(shuffle, map_idx, total_maps).map_err(|e| {
                IgniteError::Storage(format!(
                    "map-output registration ({shuffle}, map {map_idx}) failed: {e}"
                ))
            })?;
        }
        let mut done = self.done_maps.lock().unwrap();
        let set = done.entry(shuffle).or_default();
        set.insert(map_idx);
        if set.len() == total_maps {
            self.complete.lock().unwrap().insert(shuffle, total_maps);
        }
        Ok(())
    }

    /// Is the map stage of `shuffle` fully materialized locally?
    pub fn is_complete(&self, shuffle: u64) -> bool {
        self.complete.lock().unwrap().contains_key(&shuffle)
    }

    /// Number of map outputs for a completed shuffle. Falls back to the
    /// cluster map-output table when the map stage ran on other workers.
    pub fn map_count(&self, shuffle: u64) -> Option<usize> {
        if let Some(n) = self.complete.lock().unwrap().get(&shuffle).copied() {
            return Some(n);
        }
        let outputs = self.locate(shuffle)?;
        if outputs.is_complete() {
            Some(outputs.total_maps)
        } else {
            None
        }
    }

    /// Cluster locate with per-shuffle caching; `None` without a net or
    /// when the master has no record.
    fn locate(&self, shuffle: u64) -> Option<MapOutputs> {
        if let Some(hit) = self.located.lock().unwrap().get(&shuffle) {
            if hit.is_complete() {
                return Some(hit.clone());
            }
        }
        let net = self.net()?;
        match net.locate(shuffle) {
            Ok(outputs) => {
                let mut cache = self.located.lock().unwrap();
                cache.insert(shuffle, outputs.clone());
                Some(outputs)
            }
            Err(e) => {
                log::debug!(target: "shuffle", "locate({shuffle}) failed: {e}");
                None
            }
        }
    }

    /// Fetch one bucket, decoded — the single read API for reduce tasks.
    /// Resolution order: memory, disk (transparent read-back of spills),
    /// remote worker via `shuffle.fetch`. `Err` when missing everywhere
    /// (triggers stage recompute through lineage).
    pub fn fetch_bucket<T: Decode>(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
    ) -> Result<Vec<T>> {
        let bytes = self.fetch_bucket_bytes(shuffle, map_idx, reduce_idx)?;
        from_bytes(&bytes)
    }

    /// Fetch one bucket's encoded bytes through the tier chain.
    pub fn fetch_bucket_bytes(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
    ) -> Result<Arc<Vec<u8>>> {
        metrics::global().counter("shuffle.buckets.read").inc();
        if let Some(bytes) = self.local_bucket_bytes(shuffle, map_idx, reduce_idx) {
            return Ok(bytes);
        }
        // Remote tier.
        if let Some(net) = self.net() {
            if let Some(outputs) = self.locate(shuffle) {
                if let Some(addr) = outputs.addr_of(map_idx) {
                    if addr != net.local_addr() {
                        let t0 = std::time::Instant::now();
                        match net.fetch(addr, shuffle, map_idx, reduce_idx) {
                            Ok(bytes) => {
                                metrics::global().counter("shuffle.remote.fetches").inc();
                                metrics::global()
                                    .counter("shuffle.remote.bytes")
                                    .add(bytes.len() as u64);
                                metrics::global()
                                    .histogram("shuffle.fetch.latency")
                                    .record(t0.elapsed());
                                return Ok(Arc::new(bytes));
                            }
                            Err(e) => {
                                // The cached location may be stale (worker
                                // died, block recomputed elsewhere): drop
                                // it so the retry re-asks the master.
                                self.located.lock().unwrap().remove(&shuffle);
                                return Err(e);
                            }
                        }
                    }
                }
            }
        }
        Err(IgniteError::Storage(format!(
            "missing shuffle bucket ({shuffle}, map {map_idx}, reduce {reduce_idx})"
        )))
    }

    /// Read a bucket from the local tiers only (memory, then disk). This
    /// is what the worker's `shuffle.fetch` endpoint serves — remote
    /// requests must never recurse back into the remote tier.
    pub fn local_bucket_bytes(
        &self,
        shuffle: u64,
        map_idx: usize,
        reduce_idx: usize,
    ) -> Option<Arc<Vec<u8>>> {
        let key = (shuffle, map_idx, reduce_idx);
        if let Some(bytes) = self.buckets.read().unwrap().get(&key) {
            return Some(bytes.clone());
        }
        if self.spilled.lock().unwrap().contains(&key) {
            if let Some(disk) = &self.disk {
                if let Some(bytes) = disk.get_bytes(&block_id(shuffle, map_idx, reduce_idx)) {
                    metrics::global().counter("shuffle.spill.readbacks").inc();
                    return Some(Arc::new(bytes));
                }
            }
        }
        None
    }

    /// Drop a whole shuffle (fault injection: lose the map outputs, or
    /// normal cleanup after a job), from memory and disk.
    pub fn clear_shuffle(&self, shuffle: u64) {
        let keys: Vec<BlockKey> = self
            .buckets
            .read()
            .unwrap()
            .keys()
            .chain(self.spilled.lock().unwrap().iter())
            .filter(|(s, _, _)| *s == shuffle)
            .copied()
            .collect();
        for key in keys {
            self.drop_block(&key);
        }
        self.done_maps.lock().unwrap().remove(&shuffle);
        self.complete.lock().unwrap().remove(&shuffle);
        self.located.lock().unwrap().remove(&shuffle);
    }

    /// Drop a single map task's outputs (models losing one worker's local
    /// shuffle files), including spilled blocks — a lineage recompute
    /// re-registers them through the normal `put_bucket` path.
    pub fn lose_map_output(&self, shuffle: u64, map_idx: usize) {
        let keys: Vec<BlockKey> = self
            .buckets
            .read()
            .unwrap()
            .keys()
            .chain(self.spilled.lock().unwrap().iter())
            .filter(|(s, m, _)| *s == shuffle && *m == map_idx)
            .copied()
            .collect();
        for key in keys {
            self.drop_block(&key);
        }
        let mut done = self.done_maps.lock().unwrap();
        if let Some(set) = done.get_mut(&shuffle) {
            set.remove(&map_idx);
        }
        self.complete.lock().unwrap().remove(&shuffle);
    }

    /// Total buckets registered locally (both tiers).
    pub fn bucket_count(&self) -> usize {
        self.buckets.read().unwrap().len() + self.spilled.lock().unwrap().len()
    }

    /// Buckets currently spilled to disk.
    pub fn spilled_count(&self) -> usize {
        self.spilled.lock().unwrap().len()
    }

    /// Encoded bytes currently held in memory.
    pub fn mem_used(&self) -> usize {
        self.mem_used.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn disk() -> Arc<DiskStore> {
        Arc::new(DiskStore::new("/tmp/mpignite-test-shuffle").unwrap())
    }

    #[test]
    fn partitioner_is_deterministic_and_in_range() {
        let p = HashPartitioner::new(7);
        for key in 0..1000u64 {
            let a = p.partition(&key);
            let b = p.partition(&key);
            assert_eq!(a, b);
            assert!(a < 7);
        }
    }

    #[test]
    fn partitioner_spreads_keys() {
        let p = HashPartitioner::new(4);
        let mut counts = [0usize; 4];
        for key in 0..1000u64 {
            counts[p.partition(&key)] += 1;
        }
        for c in counts {
            assert!(c > 150, "partition badly skewed: {counts:?}");
        }
    }

    #[test]
    fn stable_hasher_locked_by_test_vectors() {
        // These vectors pin the algorithm: if any of them changes, the
        // on-the-wire partition assignment changed — a breaking change
        // for mixed-version clusters. Recompute only deliberately.
        fn h<T: Hash>(v: T) -> u64 {
            let mut s = StableHasher::new();
            v.hash(&mut s);
            s.finish()
        }
        assert_eq!(h(0u64), h(0u64));
        assert_ne!(h(0u64), h(1u64));
        assert_ne!(h("a"), h("b"));
        assert_ne!(h(("ab", "c")), h(("a", "bc")), "length folding separates concatenations");
        // Same value hashed in two freshly-built hashers agrees (no
        // per-process randomness, unlike RandomState).
        let mut s1 = StableHasher::new();
        let mut s2 = StableHasher::new();
        "stability".hash(&mut s1);
        "stability".hash(&mut s2);
        assert_eq!(s1.finish(), s2.finish());
    }

    #[test]
    fn bucket_roundtrip_and_completion() {
        let sm = ShuffleManager::default();
        sm.put_bucket(1, 0, 0, vec![("a".to_string(), 1u64)]);
        sm.put_bucket(1, 0, 1, vec![("b".to_string(), 2u64)]);
        sm.map_done(1, 0, 2).unwrap();
        assert!(!sm.is_complete(1), "one of two maps done");
        sm.put_bucket(1, 1, 0, vec![("c".to_string(), 3u64)]);
        sm.put_bucket(1, 1, 1, Vec::<(String, u64)>::new());
        sm.map_done(1, 1, 2).unwrap();
        assert!(sm.is_complete(1));
        assert_eq!(sm.map_count(1), Some(2));

        let b: Vec<(String, u64)> = sm.fetch_bucket(1, 0, 1).unwrap();
        assert_eq!(b, vec![("b".to_string(), 2)]);
    }

    #[test]
    fn missing_bucket_is_an_error() {
        let sm = ShuffleManager::default();
        assert!(sm.fetch_bucket::<(u64, u64)>(9, 0, 0).is_err());
    }

    #[test]
    fn wrong_type_is_an_error() {
        let sm = ShuffleManager::default();
        sm.put_bucket(2, 0, 0, vec![1u64]);
        // Decoding u64 buckets as (String, u64) pairs must fail cleanly.
        assert!(sm.fetch_bucket::<(String, u64)>(2, 0, 0).is_err());
    }

    #[test]
    fn lose_map_output_invalidates_completion() {
        let sm = ShuffleManager::default();
        sm.put_bucket(3, 0, 0, vec![1u64]);
        sm.map_done(3, 0, 1).unwrap();
        assert!(sm.is_complete(3));
        sm.lose_map_output(3, 0);
        assert!(!sm.is_complete(3));
        assert!(sm.fetch_bucket::<u64>(3, 0, 0).is_err());
    }

    #[test]
    fn clear_shuffle_removes_only_that_shuffle() {
        let sm = ShuffleManager::default();
        sm.put_bucket(4, 0, 0, vec![1u64]);
        sm.put_bucket(5, 0, 0, vec![2u64]);
        sm.clear_shuffle(4);
        assert!(sm.fetch_bucket::<u64>(4, 0, 0).is_err());
        assert!(sm.fetch_bucket::<u64>(5, 0, 0).is_ok());
    }

    #[test]
    fn speculative_duplicate_put_is_idempotent() {
        let sm = ShuffleManager::default();
        sm.put_bucket(6, 0, 0, vec![1u64, 2]);
        let used_once = sm.mem_used();
        sm.put_bucket(6, 0, 0, vec![1u64, 2]); // same content, second attempt
        assert_eq!(sm.mem_used(), used_once, "duplicate put must not double-count");
        let b: Vec<u64> = sm.fetch_bucket(6, 0, 0).unwrap();
        assert_eq!(b, vec![1, 2]);
    }

    #[test]
    fn zero_budget_spills_everything_and_reads_back() {
        let sm = ShuffleManager::new(0, Some(disk()));
        sm.put_bucket(7, 0, 0, vec![(1u64, 10u64), (2, 20)]);
        sm.put_bucket(7, 0, 1, vec![(3u64, 30u64)]);
        assert_eq!(sm.spilled_count(), 2, "budget 0 spills every bucket");
        assert_eq!(sm.mem_used(), 0);
        let b: Vec<(u64, u64)> = sm.fetch_bucket(7, 0, 0).unwrap();
        assert_eq!(b, vec![(1, 10), (2, 20)]);
        let b: Vec<(u64, u64)> = sm.fetch_bucket(7, 0, 1).unwrap();
        assert_eq!(b, vec![(3, 30)]);
    }

    #[test]
    fn buckets_spill_past_budget_then_clear() {
        // ~each encoded bucket is >8 bytes; a 64-byte budget takes a few
        // then spills the rest.
        let sm = ShuffleManager::new(64, Some(disk()));
        for m in 0..16usize {
            sm.put_bucket(8, m, 0, vec![m as u64, 1, 2, 3]);
        }
        assert!(sm.spilled_count() > 0, "over-budget buckets must spill");
        assert!(sm.mem_used() <= 64, "memory stays within budget");
        for m in 0..16usize {
            let b: Vec<u64> = sm.fetch_bucket(8, m, 0).unwrap();
            assert_eq!(b[0], m as u64, "spilled buckets read back");
        }
        sm.clear_shuffle(8);
        assert_eq!(sm.bucket_count(), 0);
        assert_eq!(sm.spilled_count(), 0);
        assert_eq!(sm.mem_used(), 0);
    }

    #[test]
    fn lose_map_output_drops_spilled_blocks_too() {
        let sm = ShuffleManager::new(0, Some(disk()));
        sm.put_bucket(9, 0, 0, vec![1u64]);
        sm.map_done(9, 0, 1).unwrap();
        assert_eq!(sm.spilled_count(), 1);
        sm.lose_map_output(9, 0);
        assert_eq!(sm.spilled_count(), 0);
        assert!(sm.fetch_bucket::<u64>(9, 0, 0).is_err());
        // Recompute path: re-register and read back.
        sm.put_bucket(9, 0, 0, vec![1u64]);
        sm.map_done(9, 0, 1).unwrap();
        assert!(sm.is_complete(9));
        assert_eq!(sm.fetch_bucket::<u64>(9, 0, 0).unwrap(), vec![1]);
    }

    struct OneBucketNet {
        bytes: Vec<u8>,
        fetches: AtomicUsize,
    }

    impl ShuffleNet for OneBucketNet {
        fn register(&self, _s: u64, _m: usize, _t: usize) -> Result<()> {
            Ok(())
        }

        fn locate(&self, _s: u64) -> Result<MapOutputs> {
            Ok(MapOutputs {
                total_maps: 1,
                locations: HashMap::from([(0, "peer:1".to_string())]),
            })
        }

        fn fetch(&self, addr: &str, _s: u64, _m: usize, _r: usize) -> Result<Vec<u8>> {
            assert_eq!(addr, "peer:1");
            self.fetches.fetch_add(1, Ordering::SeqCst);
            Ok(self.bytes.clone())
        }

        fn local_addr(&self) -> String {
            "self:0".to_string()
        }
    }

    #[test]
    fn remote_tier_fetches_missing_buckets() {
        let sm = ShuffleManager::default();
        let net = Arc::new(OneBucketNet {
            bytes: to_bytes(&vec![(7u64, 70u64)]),
            fetches: AtomicUsize::new(0),
        });
        sm.set_net(net.clone());
        // Not present locally in any tier → pulled over the net hook.
        let b: Vec<(u64, u64)> = sm.fetch_bucket(11, 0, 0).unwrap();
        assert_eq!(b, vec![(7, 70)]);
        assert_eq!(net.fetches.load(Ordering::SeqCst), 1);
        // map_count resolves through locate() for remote-only shuffles.
        assert_eq!(sm.map_count(11), Some(1));
    }
}
