//! Metrics substrate: lock-free counters, gauges, and log-bucket latency
//! histograms, collected in a process-wide registry. Every layer (RPC
//! bytes, comm messages, scheduler tasks, block store hits) reports here;
//! the bench harness and the E2E driver print the registry at exit.

use crate::error::Result;
use crate::ser::{Decode, Encode, Reader};
use once_cell::sync::Lazy;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (queue depths, cached bytes).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.value.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Histogram over `[1ns, ~18s]` with 2 buckets per power of two — compact
/// (128 buckets), lock-free recording, ~±25% quantile resolution, plenty
/// for latency *shape* comparisons.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

const NUM_BUCKETS: usize = 128;

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    fn bucket_index(ns: u64) -> usize {
        if ns == 0 {
            return 0;
        }
        let log2 = 63 - ns.leading_zeros() as usize;
        // Two buckets per octave: the second kicks in at 1.5 * 2^log2.
        let half = usize::from(ns >= (1u64 << log2) + (1u64 << log2) / 2);
        (log2 * 2 + half).min(NUM_BUCKETS - 1)
    }

    fn bucket_value(idx: usize) -> u64 {
        let log2 = idx / 2;
        let base = 1u64 << log2;
        if idx % 2 == 0 {
            base
        } else {
            base + base / 2
        }
    }

    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[Self::bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Approximate quantile (`0.0..=1.0`) from bucket lower bounds.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_value(i);
            }
        }
        self.max_ns()
    }

    /// Freeze the full bucket state into a wire-encodable snapshot, the
    /// unit of cross-process histogram aggregation (`metrics.pull`).
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
            max_ns: self.max_ns.load(Ordering::Relaxed),
        }
    }

    /// Fold a remote snapshot into this histogram bucket-by-bucket, so
    /// merged quantiles are exactly what one histogram observing both
    /// processes' samples would report.
    pub fn merge(&self, snap: &HistogramSnapshot) {
        for (i, n) in snap.buckets.iter().enumerate().take(NUM_BUCKETS) {
            if *n > 0 {
                self.buckets[i].fetch_add(*n, Ordering::Relaxed);
            }
        }
        self.count.fetch_add(snap.count, Ordering::Relaxed);
        self.sum_ns.fetch_add(snap.sum_ns, Ordering::Relaxed);
        self.max_ns.fetch_max(snap.max_ns, Ordering::Relaxed);
    }
}

/// Full-fidelity histogram state (every bucket, not just summary
/// quantiles), codec-encodable for the `metrics.pull` RPC.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistogramSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum_ns: u64,
    pub max_ns: u64,
}

impl HistogramSnapshot {
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Approximate quantile from bucket lower bounds (same math as
    /// [`Histogram::quantile_ns`]).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return Histogram::bucket_value(i);
            }
        }
        self.max_ns
    }

    /// Bucket-wise merge of another snapshot into this one.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (i, n) in other.buckets.iter().enumerate() {
            self.buckets[i] += n;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

impl Encode for HistogramSnapshot {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.buckets.encode(buf);
        self.count.encode(buf);
        self.sum_ns.encode(buf);
        self.max_ns.encode(buf);
    }
}

impl Decode for HistogramSnapshot {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(HistogramSnapshot {
            buckets: Vec::decode(r)?,
            count: u64::decode(r)?,
            sum_ns: u64::decode(r)?,
            max_ns: u64::decode(r)?,
        })
    }
}

/// A whole registry frozen for the wire: the `metrics.pull` response
/// body, and the unit [`crate::cluster::Master::cluster_metrics`]
/// merges. All three vectors are sorted by name.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct RegistrySnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

impl RegistrySnapshot {
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .binary_search_by(|(k, _)| k.as_str().cmp(name))
            .map(|i| self.counters[i].1)
            .unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges
            .binary_search_by(|(k, _)| k.as_str().cmp(name))
            .map(|i| self.gauges[i].1)
            .unwrap_or(0)
    }

    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms
            .binary_search_by(|(k, _)| k.as_str().cmp(name))
            .map(|i| &self.histograms[i].1)
            .ok()
    }

    /// Merge another process's snapshot into this cluster view: counters
    /// and gauges sum by name, histograms merge bucket-by-bucket.
    pub fn merge(&mut self, other: &RegistrySnapshot) {
        for (k, v) in &other.counters {
            match self.counters.binary_search_by(|(n, _)| n.cmp(k)) {
                Ok(i) => self.counters[i].1 += v,
                Err(i) => self.counters.insert(i, (k.clone(), *v)),
            }
        }
        for (k, v) in &other.gauges {
            match self.gauges.binary_search_by(|(n, _)| n.cmp(k)) {
                Ok(i) => self.gauges[i].1 += v,
                Err(i) => self.gauges.insert(i, (k.clone(), *v)),
            }
        }
        for (k, h) in &other.histograms {
            match self.histograms.binary_search_by(|(n, _)| n.cmp(k)) {
                Ok(i) => self.histograms[i].1.merge(h),
                Err(i) => self.histograms.insert(i, (k.clone(), h.clone())),
            }
        }
    }
}

impl Encode for RegistrySnapshot {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.counters.encode(buf);
        self.gauges.encode(buf);
        self.histograms.encode(buf);
    }
}

impl Decode for RegistrySnapshot {
    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(RegistrySnapshot {
            counters: Vec::decode(r)?,
            gauges: Vec::decode(r)?,
            histograms: Vec::decode(r)?,
        })
    }
}

/// A snapshot row for reporting.
#[derive(Debug, Clone)]
pub enum MetricValue {
    Counter(u64),
    Gauge(i64),
    Histogram { count: u64, mean_ns: f64, p50_ns: u64, p99_ns: u64, max_ns: u64 },
}

/// Registry of named metrics. One global instance ([`global`]) plus
/// per-test local instances.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut g = self.counters.lock().unwrap();
        g.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut g = self.gauges.lock().unwrap();
        g.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut g = self.histograms.lock().unwrap();
        g.entry(name.to_string()).or_default().clone()
    }

    /// Sorted snapshot of everything.
    pub fn snapshot(&self) -> BTreeMap<String, MetricValue> {
        let mut out = BTreeMap::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.insert(k.clone(), MetricValue::Counter(v.get()));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.insert(k.clone(), MetricValue::Gauge(v.get()));
        }
        for (k, v) in self.histograms.lock().unwrap().iter() {
            out.insert(
                k.clone(),
                MetricValue::Histogram {
                    count: v.count(),
                    mean_ns: v.mean_ns(),
                    p50_ns: v.quantile_ns(0.5),
                    p99_ns: v.quantile_ns(0.99),
                    max_ns: v.max_ns(),
                },
            );
        }
        out
    }

    /// Freeze the whole registry (full histogram buckets included) into
    /// the wire-encodable form `metrics.pull` ships.
    pub fn wire_snapshot(&self) -> RegistrySnapshot {
        RegistrySnapshot {
            counters: self
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            gauges: self
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.get()))
                .collect(),
            histograms: self
                .histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.snapshot()))
                .collect(),
        }
    }

    /// Fold a remote snapshot into this registry's live metrics.
    pub fn merge_snapshot(&self, snap: &RegistrySnapshot) {
        for (k, v) in &snap.counters {
            self.counter(k).add(*v);
        }
        for (k, v) in &snap.gauges {
            self.gauge(k).add(*v);
        }
        for (k, h) in &snap.histograms {
            self.histogram(k).merge(h);
        }
    }

    /// Text report, one line per metric, durations humanized
    /// (ns → µs/ms/s). Histograms sort after the scalar metrics with
    /// their names and counts column-aligned.
    pub fn report(&self) -> String {
        self.render_report(false)
    }

    /// The raw-nanosecond report form (`ignite.metrics.report.raw.ns`),
    /// kept for test assertions and machine diffing.
    pub fn report_raw(&self) -> String {
        self.render_report(true)
    }

    fn render_report(&self, raw_ns: bool) -> String {
        let fmt_ns = |ns: u64| -> String {
            if raw_ns {
                format!("{ns}ns")
            } else {
                crate::util::fmt_duration(Duration::from_nanos(ns))
            }
        };
        let mut out = String::new();
        let mut hists: Vec<(String, u64, f64, u64, u64, u64)> = Vec::new();
        for (k, v) in self.snapshot() {
            match v {
                MetricValue::Counter(c) => out.push_str(&format!("{k} = {c}\n")),
                MetricValue::Gauge(g) => out.push_str(&format!("{k} = {g}\n")),
                MetricValue::Histogram { count, mean_ns, p50_ns, p99_ns, max_ns } => {
                    hists.push((k, count, mean_ns, p50_ns, p99_ns, max_ns));
                }
            }
        }
        // snapshot() is a BTreeMap, so `hists` is already name-sorted;
        // align the name and count columns so the eye can scan them.
        let name_w = hists.iter().map(|(k, ..)| k.len()).max().unwrap_or(0);
        let count_w =
            hists.iter().map(|(_, c, ..)| c.to_string().len()).max().unwrap_or(0);
        for (k, count, mean_ns, p50_ns, p99_ns, max_ns) in hists {
            out.push_str(&format!(
                "{k:<name_w$} = count={count:<count_w$} mean={} p50={} p99={} max={}\n",
                fmt_ns(mean_ns.round() as u64),
                fmt_ns(p50_ns),
                fmt_ns(p99_ns),
                fmt_ns(max_ns),
            ));
        }
        out
    }
}

static GLOBAL: Lazy<MetricsRegistry> = Lazy::new(MetricsRegistry::new);

/// Process-wide registry.
pub fn global() -> &'static MetricsRegistry {
    &GLOBAL
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("rpc.bytes");
        c.add(10);
        c.inc();
        assert_eq!(c.get(), 11);
        // Same name returns the same underlying counter.
        assert_eq!(reg.counter("rpc.bytes").get(), 11);

        let g = reg.gauge("queue.depth");
        g.set(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn histogram_quantiles_are_ordered() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000); // 1µs..1ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
        assert!(p50 >= 250_000 && p50 <= 1_000_000, "p50 {p50} out of band");
        assert!(h.mean_ns() > 0.0);
        assert_eq!(h.max_ns(), 1_000_000);
    }

    #[test]
    fn histogram_bucket_roundtrip_monotone() {
        let mut last = 0;
        for idx in 0..NUM_BUCKETS {
            let v = Histogram::bucket_value(idx);
            assert!(v >= last);
            last = v;
        }
        // A value lands in a bucket whose lower bound does not exceed it.
        for ns in [1u64, 2, 3, 100, 1_000, 123_456, u32::MAX as u64] {
            let idx = Histogram::bucket_index(ns);
            assert!(Histogram::bucket_value(idx) <= ns.max(1));
        }
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::default();
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn snapshot_and_report() {
        let reg = MetricsRegistry::new();
        reg.counter("a").inc();
        reg.gauge("b").set(-1);
        reg.histogram("c").record(Duration::from_micros(5));
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 3);
        let report = reg.report();
        assert!(report.contains("a = 1"));
        assert!(report.contains("b = -1"));
        assert!(report.contains("count=1"));
    }

    #[test]
    fn global_registry_is_shared() {
        global().counter("test.global").inc();
        assert!(global().counter("test.global").get() >= 1);
    }

    #[test]
    fn histogram_snapshot_round_trips_and_merges() {
        use crate::ser::{from_bytes, to_bytes};
        let a = Histogram::default();
        let b = Histogram::default();
        for i in 1..=100u64 {
            a.record_ns(i * 1_000);
            b.record_ns(i * 1_000_000);
        }
        let snap_b = b.snapshot();
        let back: HistogramSnapshot = from_bytes(&to_bytes(&snap_b)).unwrap();
        assert_eq!(back, snap_b);

        // Histogram::merge(&snapshot): `a` absorbs `b`'s samples exactly.
        a.merge(&snap_b);
        assert_eq!(a.count(), 200);
        assert_eq!(a.max_ns(), b.max_ns());
        let both = Histogram::default();
        for i in 1..=100u64 {
            both.record_ns(i * 1_000);
            both.record_ns(i * 1_000_000);
        }
        assert_eq!(a.snapshot(), both.snapshot());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile_ns(q), both.quantile_ns(q));
        }
    }

    #[test]
    fn registry_snapshot_merge_sums_bit_exactly() {
        use crate::ser::{from_bytes, to_bytes};
        let r1 = MetricsRegistry::new();
        let r2 = MetricsRegistry::new();
        r1.counter("tasks").add(7);
        r2.counter("tasks").add(5);
        r2.counter("only.two").add(3);
        r1.gauge("depth").set(2);
        r2.gauge("depth").set(4);
        r1.histogram("lat").record_ns(1_000);
        r2.histogram("lat").record_ns(2_000_000);

        let s1 = r1.wire_snapshot();
        let s2 = r2.wire_snapshot();
        let back: RegistrySnapshot = from_bytes(&to_bytes(&s1)).unwrap();
        assert_eq!(back, s1);

        let mut cluster = RegistrySnapshot::default();
        cluster.merge(&s1);
        cluster.merge(&s2);
        assert_eq!(cluster.counter("tasks"), 12);
        assert_eq!(cluster.counter("only.two"), 3);
        assert_eq!(cluster.gauge("depth"), 6);
        let lat = cluster.histogram("lat").unwrap();
        assert_eq!(lat.count, 2);
        assert_eq!(lat.sum_ns, 2_001_000);
        assert_eq!(lat.max_ns, 2_000_000);

        // merge_snapshot folds the cluster view back into a registry.
        let view = MetricsRegistry::new();
        view.merge_snapshot(&cluster);
        assert_eq!(view.counter("tasks").get(), 12);
        assert_eq!(view.histogram("lat").count(), 2);
    }

    #[test]
    fn report_humanizes_and_raw_form_keeps_ns() {
        let reg = MetricsRegistry::new();
        reg.histogram("lat.long.name").record(Duration::from_millis(5));
        reg.histogram("lat").record(Duration::from_micros(2));
        reg.counter("n").inc();
        let human = reg.report();
        assert!(human.contains("n = 1"));
        assert!(human.contains("count=1"));
        assert!(human.contains("ms"), "expected humanized ms in: {human}");
        // Names pad to the longest histogram so the columns align.
        let eq_cols: Vec<usize> = human
            .lines()
            .filter(|l| l.contains("count="))
            .map(|l| l.find(" = ").unwrap())
            .collect();
        assert_eq!(eq_cols.len(), 2);
        assert_eq!(eq_cols[0], eq_cols[1], "histogram columns misaligned:\n{human}");
        let raw = reg.report_raw();
        assert!(raw.contains("count=1"));
        assert!(raw.contains("max=5242880ns") || raw.contains("max=5000000ns"), "raw: {raw}");
    }
}
