//! Metrics substrate: lock-free counters, gauges, and log-bucket latency
//! histograms, collected in a process-wide registry. Every layer (RPC
//! bytes, comm messages, scheduler tasks, block store hits) reports here;
//! the bench harness and the E2E driver print the registry at exit.

use once_cell::sync::Lazy;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Instantaneous signed value (queue depths, cached bytes).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, d: i64) {
        self.value.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Histogram over `[1ns, ~18s]` with 2 buckets per power of two — compact
/// (128 buckets), lock-free recording, ~±25% quantile resolution, plenty
/// for latency *shape* comparisons.
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

const NUM_BUCKETS: usize = 128;

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    fn bucket_index(ns: u64) -> usize {
        if ns == 0 {
            return 0;
        }
        let log2 = 63 - ns.leading_zeros() as usize;
        // Two buckets per octave: the second kicks in at 1.5 * 2^log2.
        let half = usize::from(ns >= (1u64 << log2) + (1u64 << log2) / 2);
        (log2 * 2 + half).min(NUM_BUCKETS - 1)
    }

    fn bucket_value(idx: usize) -> u64 {
        let log2 = idx / 2;
        let base = 1u64 << log2;
        if idx % 2 == 0 {
            base
        } else {
            base + base / 2
        }
    }

    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn record_ns(&self, ns: u64) {
        self.buckets[Self::bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Approximate quantile (`0.0..=1.0`) from bucket lower bounds.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Self::bucket_value(i);
            }
        }
        self.max_ns()
    }
}

/// A snapshot row for reporting.
#[derive(Debug, Clone)]
pub enum MetricValue {
    Counter(u64),
    Gauge(i64),
    Histogram { count: u64, mean_ns: f64, p50_ns: u64, p99_ns: u64, max_ns: u64 },
}

/// Registry of named metrics. One global instance ([`global`]) plus
/// per-test local instances.
#[derive(Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut g = self.counters.lock().unwrap();
        g.entry(name.to_string()).or_default().clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut g = self.gauges.lock().unwrap();
        g.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut g = self.histograms.lock().unwrap();
        g.entry(name.to_string()).or_default().clone()
    }

    /// Sorted snapshot of everything.
    pub fn snapshot(&self) -> BTreeMap<String, MetricValue> {
        let mut out = BTreeMap::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.insert(k.clone(), MetricValue::Counter(v.get()));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.insert(k.clone(), MetricValue::Gauge(v.get()));
        }
        for (k, v) in self.histograms.lock().unwrap().iter() {
            out.insert(
                k.clone(),
                MetricValue::Histogram {
                    count: v.count(),
                    mean_ns: v.mean_ns(),
                    p50_ns: v.quantile_ns(0.5),
                    p99_ns: v.quantile_ns(0.99),
                    max_ns: v.max_ns(),
                },
            );
        }
        out
    }

    /// Text report, one line per metric.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.snapshot() {
            match v {
                MetricValue::Counter(c) => out.push_str(&format!("{k} = {c}\n")),
                MetricValue::Gauge(g) => out.push_str(&format!("{k} = {g}\n")),
                MetricValue::Histogram { count, mean_ns, p50_ns, p99_ns, max_ns } => {
                    out.push_str(&format!(
                        "{k} = count={count} mean={mean_ns:.0}ns p50={p50_ns}ns p99={p99_ns}ns max={max_ns}ns\n"
                    ));
                }
            }
        }
        out
    }
}

static GLOBAL: Lazy<MetricsRegistry> = Lazy::new(MetricsRegistry::new);

/// Process-wide registry.
pub fn global() -> &'static MetricsRegistry {
    &GLOBAL
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("rpc.bytes");
        c.add(10);
        c.inc();
        assert_eq!(c.get(), 11);
        // Same name returns the same underlying counter.
        assert_eq!(reg.counter("rpc.bytes").get(), 11);

        let g = reg.gauge("queue.depth");
        g.set(5);
        g.add(-2);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn histogram_quantiles_are_ordered() {
        let h = Histogram::default();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000); // 1µs..1ms
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= p99, "p50 {p50} > p99 {p99}");
        assert!(p50 >= 250_000 && p50 <= 1_000_000, "p50 {p50} out of band");
        assert!(h.mean_ns() > 0.0);
        assert_eq!(h.max_ns(), 1_000_000);
    }

    #[test]
    fn histogram_bucket_roundtrip_monotone() {
        let mut last = 0;
        for idx in 0..NUM_BUCKETS {
            let v = Histogram::bucket_value(idx);
            assert!(v >= last);
            last = v;
        }
        // A value lands in a bucket whose lower bound does not exceed it.
        for ns in [1u64, 2, 3, 100, 1_000, 123_456, u32::MAX as u64] {
            let idx = Histogram::bucket_index(ns);
            assert!(Histogram::bucket_value(idx) <= ns.max(1));
        }
    }

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::default();
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn snapshot_and_report() {
        let reg = MetricsRegistry::new();
        reg.counter("a").inc();
        reg.gauge("b").set(-1);
        reg.histogram("c").record(Duration::from_micros(5));
        let snap = reg.snapshot();
        assert_eq!(snap.len(), 3);
        let report = reg.report();
        assert!(report.contains("a = 1"));
        assert!(report.contains("b = -1"));
        assert!(report.contains("count=1"));
    }

    #[test]
    fn global_registry_is_shared() {
        global().counter("test.global").inc();
        assert!(global().counter("test.global").get() >= 1);
    }
}
