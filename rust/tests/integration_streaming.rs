//! Streaming integration: a ≥200-micro-batch windowed word-count soak
//! through the job server — under seeded task-fault chaos, with a worker
//! killed and replaced mid-stream — whose finalized output must be
//! bit-identical to the equivalent single batch job; backpressure
//! admission stalls when the cluster lags; `wait_job` failure surfacing;
//! and the streaming-iterative peer sink (online k-means).

use mpignite::apps;
use mpignite::closure::register_op;
use mpignite::cluster::Worker;
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use mpignite::streaming::{batch_oracle_plan, sort_rows, StreamBatch};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Heartbeat-timing-sensitive clusters; serialized like the other
/// cluster suites so concurrent test threads don't turn timing
/// assumptions into flakes.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn conf() -> IgniteConf {
    let mut c = IgniteConf::new();
    c.set("ignite.worker.heartbeat.ms", "50");
    c.set("ignite.worker.timeout.ms", "600");
    c.set("ignite.worker.slots", "2");
    c
}

fn register_ops() {
    // Str line -> List of List([Str(word), I64(1)]) pairs.
    register_op("stream.it.word_pairs", |v| match v {
        Value::Str(s) => Ok(Value::List(
            s.split_whitespace()
                .map(|w| Value::List(vec![Value::Str(w.to_string()), Value::I64(1)]))
                .collect(),
        )),
        other => Err(IgniteError::Invalid(format!(
            "word_pairs wants str, got {}",
            other.type_name()
        ))),
    });
    register_op("stream.it.nap60_inc", |v| match v {
        Value::I64(n) => {
            std::thread::sleep(Duration::from_millis(60));
            Ok(Value::I64(n + 1))
        }
        other => Err(IgniteError::Invalid(format!("nap wants i64, got {}", other.type_name()))),
    });
    register_op("stream.it.fail", |_| {
        Err(IgniteError::Invalid("stream.it.fail always fails".into()))
    });
}

fn counter(name: &str) -> u64 {
    mpignite::metrics::global().counter(name).get()
}

/// Deterministic per-batch lines: a handful of words whose mix shifts
/// with the batch index, split over 2 partitions.
fn soak_batch(t: u64) -> Vec<Vec<Value>> {
    vec![
        vec![Value::Str(format!("w{} w{} common", t % 7, (t + 1) % 5))],
        vec![Value::Str(format!("common w{}", t % 3))],
    ]
}

#[test]
fn soak_windowed_wordcount_survives_chaos_and_matches_batch_oracle() {
    let _serial = lock();
    register_ops();
    const TOTAL: u64 = 210;

    let mut c = conf();
    // Seeded chaos: attempt-0 task faults the worker retry ladder must
    // absorb. The CI soak lane overrides the seed via env (the env
    // overlay is applied at IgniteConf::new, so this explicit set wins
    // only when the env is absent).
    if std::env::var("MPIGNITE_FAULT_INJECT_SEED").is_err() {
        c.set("ignite.fault.inject.seed", "23");
    }
    let window = mpignite::streaming::WindowSpec::from_conf(&c).unwrap();
    assert_eq!(window.size, 10, "default streaming window size");

    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    let submitted0 = counter("streaming.batches.submitted");
    let completed0 = counter("streaming.batches.completed");
    let finalized0 = counter("streaming.windows.finalized");
    let reissued0 = counter("plan.tasks.reissued");
    let latency = mpignite::metrics::global().histogram("streaming.batch.latency");
    let latency_count0 = latency.count();

    let source = MemoryStreamSource::new();
    let mut replay: Vec<StreamBatch> = Vec::new();
    for t in 0..TOTAL {
        let parts = soak_batch(t);
        replay.push(StreamBatch { partitions: parts.clone(), event_time: t });
        source.push(parts, t);
    }
    source.close();

    let spec = QuerySpec::reduce(
        "soak-wc",
        vec![OpSpec::FlatMapNamed { name: "stream.it.word_pairs".into() }],
        AggSpec::SumI64,
        2,
    )
    .windowed(window);
    let mut query = sc.streaming().query(Box::new(source), spec.clone()).unwrap();

    // Drive the stream by hand so the worker kill + replacement lands
    // mid-stream, and so watermark pruning is observable while batches
    // are still flowing.
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut max_live_windows = 0usize;
    let mut killed = false;
    let mut replacement: Option<Arc<Worker>> = None;
    while query.batches_completed() < TOTAL {
        let cut = query.poll_once().unwrap();
        max_live_windows = max_live_windows.max(query.live_state_windows());
        if !killed && query.batches_completed() >= TOTAL / 5 {
            // Kill a worker with batches in flight, then rejoin a fresh
            // one: per-batch task re-issue must carry the stream across
            // with zero whole-query restarts.
            workers[1].kill();
            replacement = Some(Worker::start(&c, master.address()).unwrap());
            killed = true;
        }
        assert!(Instant::now() < deadline, "soak did not finish in time");
        if !cut {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    query.drain(Duration::from_secs(30)).unwrap();
    assert!(killed, "the kill must have happened mid-stream");
    drop(replacement);

    // Bit-identical to the equivalent single batch job over the same
    // batch sequence (run on a clean local engine — SumI64 is exact).
    let oracle_plan = batch_oracle_plan(&spec, &replay).unwrap();
    let oracle = IgniteContext::local(2);
    let want = sort_rows(oracle.plan_rdd(oracle_plan).collect().unwrap());
    assert_eq!(
        query.results_sorted(),
        want,
        "streamed windowed counts must equal the single batch job"
    );

    // Lineage: every batch completed exactly once, each with a job id
    // (cluster mode) and a recorded latency.
    assert_eq!(query.lineage().len(), TOTAL as usize);
    assert!(query.lineage().iter().all(|b| b.job_id.is_some() && b.latency.is_some()));

    // Watermark pruning ran DURING the stream (state never accumulated
    // across all 21 windows) and finished CLEAN: no live windows, no
    // state or batch buckets left in the driver's shuffle tiers.
    assert!(
        max_live_windows <= 3,
        "watermark must prune windows mid-stream (saw {max_live_windows} live)"
    );
    assert_eq!(query.live_state_windows(), 0);
    assert_eq!(sc.engine().shuffle.bucket_count(), 0, "drained stream leaves no buckets");
    assert_eq!(counter("streaming.windows.finalized") - finalized0, 21);

    // Acceptance metrics.
    assert_eq!(counter("streaming.batches.submitted") - submitted0, TOTAL);
    assert_eq!(counter("streaming.batches.completed") - completed0, TOTAL);
    assert_eq!(latency.count() - latency_count0, TOTAL);
    assert!(
        counter("plan.tasks.reissued") - reissued0 > 0,
        "the killed worker's in-flight batch tasks must have been re-issued"
    );
    master.shutdown();
}

#[test]
fn backpressure_stalls_admission_when_the_cluster_lags() {
    let _serial = lock();
    register_ops();
    let mut c = conf();
    c.set("ignite.streaming.max.inflight.batches", "1");
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _worker = Worker::start(&c, master.address()).unwrap();
    master.wait_for_workers(1, Duration::from_secs(5)).unwrap();

    let stalls0 = counter("streaming.backpressure.stalls");

    // Slow batches (60ms tasks) against an in-flight cap of 1: cutting
    // batch N+1 must stall until batch N's job finishes.
    let source = MemoryStreamSource::new();
    for t in 0..6u64 {
        source.push(vec![vec![Value::I64(t as i64)], vec![Value::I64(-(t as i64))]], t);
    }
    source.close();
    let spec = QuerySpec::reduce(
        "backpressure",
        vec![
            OpSpec::MapNamed { name: "stream.it.nap60_inc".into() },
            OpSpec::KeyByHash,
        ],
        AggSpec::First,
        2,
    );
    let mut query = sc.streaming().query(Box::new(source), spec).unwrap();
    query.drain(Duration::from_secs(60)).unwrap();

    assert_eq!(query.batches_completed(), 6);
    assert!(
        counter("streaming.backpressure.stalls") - stalls0 > 0,
        "admission must have stalled under the in-flight cap"
    );
    assert!(
        query.max_inflight_observed() <= 1,
        "the cap bounds concurrent batches (saw {})",
        query.max_inflight_observed()
    );
    assert_eq!(
        mpignite::metrics::global().gauge("streaming.queue.depth").get(),
        0,
        "queue depth gauge returns to zero once drained"
    );
    master.shutdown();
}

#[test]
fn wait_job_surfaces_failure_detail_timeout_and_unknown_jobs() {
    let _serial = lock();
    register_ops();
    let c = conf();
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _worker = Worker::start(&c, master.address()).unwrap();
    master.wait_for_workers(1, Duration::from_secs(5)).unwrap();

    // Unknown job ids are an Invalid error, not an endless poll.
    let err = master.wait_job(u64::MAX, Duration::from_secs(1)).unwrap_err();
    assert!(err.to_string().contains("unknown job"), "got: {err}");

    // A deterministically failing op exhausts the task retry ladder and
    // fails the job; wait_job must surface the failure detail instead of
    // timing out opaquely.
    let session = master.new_session();
    let plan = sc.parallelize_values_with(vec![Value::I64(1)], 1).map_named("stream.it.fail");
    let job = master.submit_job(session, plan.plan()).unwrap();
    let err = master.wait_job(job, Duration::from_secs(30)).unwrap_err();
    assert!(
        matches!(err, IgniteError::Task(_)) && err.to_string().contains("failed"),
        "failure detail must surface, got: {err}"
    );

    // A live-but-slow job hits the caller's deadline with a progress-rich
    // Timeout error.
    let slow = sc.parallelize_values_with(vec![Value::I64(5)], 1).map_named("stream.it.nap60_inc");
    let job = master.submit_job(session, slow.plan()).unwrap();
    let err = master.wait_job(job, Duration::from_millis(1)).unwrap_err();
    assert!(
        matches!(err, IgniteError::Timeout(_)) && err.to_string().contains("still"),
        "expected a pending/running timeout, got: {err}"
    );
    // The job itself still completes.
    let got = master.wait_job(job, Duration::from_secs(30)).unwrap();
    assert_eq!(got, vec![Value::I64(6)]);
    master.shutdown();
}

#[test]
fn streaming_kmeans_peer_sink_refreshes_the_model_per_batch() {
    let _serial = lock();
    apps::register_kmeans_online("stream.it.kmeans", 2, 0.5);
    let sc = IgniteContext::local(2);

    // Three batches of 2-partition point clouds drifting along x: each
    // batch runs as a gang-scheduled peer section whose model update is
    // one in-stage all_reduce.
    let source = MemoryStreamSource::new();
    for t in 0..3u64 {
        let shift = t as f64 * 2.0;
        source.push(
            vec![
                vec![
                    Value::F64Vec(vec![shift, 0.0]),
                    Value::F64Vec(vec![10.0 + shift, 0.0]),
                ],
                vec![
                    Value::F64Vec(vec![shift + 0.2, 0.0]),
                    Value::F64Vec(vec![10.2 + shift, 0.0]),
                ],
            ],
            t,
        );
    }
    source.close();

    let spec = QuerySpec::peer("kmeans-online", Vec::new(), "stream.it.kmeans", 2);
    let mut query = sc.streaming().query(Box::new(source), spec).unwrap();
    query.drain(Duration::from_secs(30)).unwrap();

    assert_eq!(query.batches_completed(), 3);
    let last = query.last_batch_output().expect("final model").to_vec();
    assert_eq!(last.len(), 4, "2 ranks x k=2 model rows");
    assert!(last.iter().all(|r| matches!(r, Value::F64Vec(_))));
    // The model refreshed per batch: the final batch's output differs
    // from the first batch's (the clouds drifted).
    let first = query.results_sorted();
    assert!(!first.is_empty());
    let Value::F64Vec(c) = &last[0] else { panic!("bad model row") };
    assert!(c[0] > 0.5, "model must have tracked the drift, got {c:?}");
    assert_eq!(query.live_state_windows(), 0, "stateless query holds no window state");
}
