//! Property tests for the serializable plan IR:
//!
//! * any `PlanSpec` built from the public `PlanRdd` API round-trips
//!   encode → decode → re-encode **byte-identically** (the invariant that
//!   lets drivers and workers agree on a plan's identity);
//! * a decoded plan executed on the local engine produces exactly the
//!   same result as the equivalent closure-based `Rdd` pipeline (the
//!   driver-local fast path) on the same input.

use mpignite::closure::register_op;
use mpignite::rdd::{AggSpec, PlanRdd, PlanSpec};
use mpignite::rng::Xoshiro256;
use mpignite::ser::{from_bytes, to_bytes, Value};
use mpignite::rdd::Rdd;
use mpignite::testkit::{check, FnGen, PropConfig};
use mpignite::{IgniteContext, IgniteError};
use std::collections::HashMap;
use std::sync::Once;

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, seed: 0x914A_17E5, max_shrink: 64 }
}

static OPS: Once = Once::new();

fn register_ops() {
    OPS.call_once(|| {
        register_op("prop.double", |v| match v {
            Value::I64(x) => Ok(Value::I64(x.wrapping_mul(2))),
            other => Err(IgniteError::Invalid(format!("want i64, got {}", other.type_name()))),
        });
        register_op("prop.inc", |v| match v {
            Value::I64(x) => Ok(Value::I64(x.wrapping_add(1))),
            other => Err(IgniteError::Invalid(format!("want i64, got {}", other.type_name()))),
        });
        register_op("prop.even", |v| match v {
            Value::I64(x) => Ok(Value::Bool(x % 2 == 0)),
            other => Err(IgniteError::Invalid(format!("want i64, got {}", other.type_name()))),
        });
        register_op("prop.dup", |v| Ok(Value::List(vec![v.clone(), v])));
        register_op("prop.pair_mod7", |v| match v {
            Value::I64(x) => Ok(Value::List(vec![Value::I64(x.rem_euclid(7)), Value::I64(x)])),
            other => Err(IgniteError::Invalid(format!("want i64, got {}", other.type_name()))),
        });
        // Peer section: every rank adds the gang-wide (all-reduced) sum
        // to its rows — a value that provably needed sibling-task
        // communication to compute.
        mpignite::closure::register_peer_op("prop.peer.add_total", |comm, rows| {
            let local = rows.iter().fold(0i64, |acc, v| match v {
                Value::I64(x) => acc.wrapping_add(*x),
                _ => acc,
            });
            let total = comm.all_reduce(local, |a, b| a.wrapping_add(b))?;
            Ok(rows
                .into_iter()
                .map(|v| match v {
                    Value::I64(x) => Value::I64(x.wrapping_add(total)),
                    other => other,
                })
                .collect())
        });
    });
}

/// One step of a random pipeline, applicable to both lineage flavors.
#[derive(Debug, Clone, Copy)]
enum Step {
    Double,
    Inc,
    FilterEven,
    DupFlatMap,
    Sample(u64),
}

/// A random script: source data, partitioning, element steps, an
/// optional peer section (gang all-reduce adding the global sum to every
/// row), and whether the pipeline ends in a shuffle (`reduce_by_key`
/// mod 7).
#[derive(Debug, Clone)]
struct Script {
    data: Vec<i64>,
    parts: usize,
    steps: Vec<Step>,
    peer: bool,
    shuffle: bool,
}

fn arbitrary_script(rng: &mut Xoshiro256) -> Script {
    let n = rng.range(0, 40);
    let data: Vec<i64> = (0..n).map(|_| rng.next_below(2000) as i64 - 1000).collect();
    let parts = rng.range(1, 6);
    let steps = (0..rng.range(0, 5))
        .map(|_| match rng.next_below(5) {
            0 => Step::Double,
            1 => Step::Inc,
            2 => Step::FilterEven,
            3 => Step::DupFlatMap,
            _ => Step::Sample(rng.next_u64()),
        })
        .collect();
    Script { data, parts, steps, peer: rng.chance(0.4), shuffle: rng.chance(0.5) }
}

fn build_plan(sc: &IgniteContext, script: &Script) -> PlanRdd {
    let rows: Vec<Value> = script.data.iter().map(|&x| Value::I64(x)).collect();
    let mut plan = sc.parallelize_values_with(rows, script.parts);
    for step in &script.steps {
        plan = match step {
            Step::Double => plan.map_named("prop.double"),
            Step::Inc => plan.map_named("prop.inc"),
            Step::FilterEven => plan.filter_named("prop.even"),
            Step::DupFlatMap => plan.flat_map_named("prop.dup"),
            Step::Sample(seed) => plan.sample(0.5, *seed),
        };
    }
    if script.peer {
        plan = plan.map_partitions_peer("prop.peer.add_total");
    }
    if script.shuffle {
        plan = plan.map_named("prop.pair_mod7").reduce_by_key(3, AggSpec::SumI64);
    }
    plan
}

fn build_closure_rdd(sc: &IgniteContext, script: &Script) -> Rdd<i64> {
    let mut rdd = sc.parallelize_with(script.data.clone(), script.parts);
    for step in &script.steps {
        rdd = match step {
            Step::Double => rdd.map(|x| x.wrapping_mul(2)),
            Step::Inc => rdd.map(|x| x.wrapping_add(1)),
            Step::FilterEven => rdd.filter(|x| x % 2 == 0),
            Step::DupFlatMap => rdd.flat_map(|x| vec![x, x]),
            Step::Sample(seed) => rdd.sample(0.5, *seed),
        };
    }
    if script.peer {
        // Closure flavor of prop.peer.add_total, same math to the bit.
        rdd = rdd
            .map_partitions_peer(|comm, rows: Vec<i64>| {
                let local = rows.iter().fold(0i64, |acc, x| acc.wrapping_add(*x));
                let total = comm.all_reduce(local, |a, b| a.wrapping_add(b))?;
                Ok(rows.into_iter().map(|x| x.wrapping_add(total)).collect())
            })
            .expect("closure peer section");
    }
    rdd
}

fn plan_rows_as_i64(rows: Vec<Value>) -> Result<Vec<i64>, String> {
    rows.into_iter()
        .map(|v| match v {
            Value::I64(x) => Ok(x),
            other => Err(format!("expected i64 row, got {other:?}")),
        })
        .collect()
}

fn plan_rows_as_pairs(rows: Vec<Value>) -> Result<HashMap<i64, i64>, String> {
    let mut out = HashMap::new();
    for row in rows {
        match row {
            Value::List(l) if l.len() == 2 => match (&l[0], &l[1]) {
                (Value::I64(k), Value::I64(v)) => {
                    if out.insert(*k, *v).is_some() {
                        return Err(format!("duplicate key {k}"));
                    }
                }
                other => return Err(format!("bad pair {other:?}")),
            },
            other => return Err(format!("bad row {other:?}")),
        }
    }
    Ok(out)
}

#[test]
fn prop_plan_round_trips_byte_identically() {
    register_ops();
    let sc = IgniteContext::local(2);
    let gen = FnGen(|rng: &mut Xoshiro256| arbitrary_script(rng));
    check(cfg(150), &gen, |script| {
        let plan = build_plan(&sc, script);
        let bytes = plan.encoded();
        let decoded: PlanSpec = from_bytes(&bytes).map_err(|e| e.to_string())?;
        if &decoded != plan.plan() {
            return Err(format!("decoded tree differs: {decoded:?}"));
        }
        let re = to_bytes(&decoded);
        if re != bytes {
            return Err(format!(
                "re-encode not byte-identical ({} vs {} bytes)",
                re.len(),
                bytes.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_source_ref_plans_match_inline_source_plans() {
    register_ops();
    let sc = IgniteContext::local(4);
    let gen = FnGen(|rng: &mut Xoshiro256| arbitrary_script(rng));
    check(cfg(40), &gen, |script| {
        let inline = build_plan(&sc, script);
        // An independently-built copy of the same script (fresh shuffle
        // ids, so the two executions share no shuffle state) with every
        // Source replaced by a SourceRef whose partitions are staged in
        // the engine's broadcast manager — the decoded shape a worker
        // sees after Master::run_plan's auto-broadcast rewrite.
        let engine = sc.engine().clone();
        let mut staged: Vec<u64> = Vec::new();
        let by_ref = build_plan(&sc, script).plan().rewrite_sources(&mut |src| {
            let PlanSpec::Source { partitions } = src else { return None };
            let id = mpignite::util::next_id();
            engine.broadcast.put_value_bytes(id, &to_bytes(partitions));
            staged.push(id);
            Some(PlanSpec::SourceRef {
                broadcast_id: id,
                num_partitions: partitions.len() as u64,
            })
        });
        // Ship-shaped: encode + decode before executing.
        let decoded: PlanSpec = from_bytes(&to_bytes(&by_ref)).map_err(|e| e.to_string())?;
        let got = sc.plan_rdd(decoded).collect().map_err(|e| e.to_string())?;
        let want = inline.collect().map_err(|e| e.to_string())?;
        for id in staged {
            engine.clear_broadcast(id);
        }
        if script.shuffle {
            let got = plan_rows_as_pairs(got)?;
            let want = plan_rows_as_pairs(want)?;
            if got != want {
                return Err(format!("shuffled mismatch: got {got:?}, want {want:?}"));
            }
        } else {
            let got = plan_rows_as_i64(got)?;
            let want = plan_rows_as_i64(want)?;
            if got != want {
                return Err(format!("mismatch: got {got:?}, want {want:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decoded_plan_matches_closure_fast_path() {
    register_ops();
    let sc = IgniteContext::local(4);
    let gen = FnGen(|rng: &mut Xoshiro256| arbitrary_script(rng));
    check(cfg(60), &gen, |script| {
        // Ship-shaped: encode, decode, execute the *decoded* plan.
        let decoded: PlanSpec =
            from_bytes(&build_plan(&sc, script).encoded()).map_err(|e| e.to_string())?;
        let got = sc.plan_rdd(decoded).collect().map_err(|e| e.to_string())?;
        if script.shuffle {
            let got = plan_rows_as_pairs(got)?;
            let want = build_closure_rdd(&sc, script)
                .map(|x| (x.rem_euclid(7), x))
                .reduce_by_key(3, |a, b| a.wrapping_add(b))
                .collect_map()
                .map_err(|e| e.to_string())?;
            if got != want {
                return Err(format!("shuffled mismatch: got {got:?}, want {want:?}"));
            }
        } else {
            let got = plan_rows_as_i64(got)?;
            let want = build_closure_rdd(&sc, script).collect().map_err(|e| e.to_string())?;
            if got != want {
                return Err(format!("mismatch: got {got:?}, want {want:?}"));
            }
        }
        Ok(())
    });
}
