//! Integration tests for the data-parallel engine: multi-stage pipelines,
//! caching + eviction recompute, shuffle-loss recomputation, fault
//! injection through whole jobs, and RDD/closure interop.

use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

#[test]
fn multi_stage_pipeline_two_shuffles() {
    let sc = IgniteContext::local(4);
    // wordcount → count-by-count (two shuffle boundaries).
    let words: Vec<String> = ["a", "b", "a", "c", "b", "a", "d", "e", "d"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let counts = sc
        .parallelize(words)
        .map(|w| (w, 1i64))
        .reduce_by_key(4, |a, b| a + b) // {a:3, b:2, c:1, d:2, e:1}
        .map(|(_, c)| (c, 1i64))
        .reduce_by_key(4, |a, b| a + b) // {3:1, 2:2, 1:2}
        .collect_map()
        .unwrap();
    assert_eq!(counts[&3], 1);
    assert_eq!(counts[&2], 2);
    assert_eq!(counts[&1], 2);
}

#[test]
fn cache_computes_once_then_hits() {
    let sc = IgniteContext::local(2);
    let computed = Arc::new(AtomicUsize::new(0));
    let c2 = computed.clone();
    let rdd = sc
        .parallelize_with((0..100i64).collect(), 4)
        .map(move |x| {
            c2.fetch_add(1, Ordering::SeqCst);
            x * 2
        })
        .cache();
    assert_eq!(rdd.count().unwrap(), 100);
    let first = computed.load(Ordering::SeqCst);
    assert_eq!(first, 100, "computed each element once");
    // Second action: served from cache.
    assert_eq!(rdd.collect().unwrap().len(), 100);
    assert_eq!(computed.load(Ordering::SeqCst), first, "no recompute on cache hit");
}

#[test]
fn cache_eviction_recomputes_from_lineage() {
    let mut conf = IgniteConf::new();
    conf.set("ignite.storage.memory.max", "4096"); // tiny budget
    conf.set("ignite.worker.slots", "2");
    let sc = IgniteContext::with_conf(conf).unwrap();
    let computed = Arc::new(AtomicUsize::new(0));
    let c2 = computed.clone();
    // Each partition ~2000 bytes of i64 → several partitions can't all fit.
    let rdd = sc
        .parallelize_with((0..1000i64).collect(), 8)
        .map(move |x| {
            c2.fetch_add(1, Ordering::SeqCst);
            x
        })
        .cache();
    assert_eq!(rdd.count().unwrap(), 1000);
    let first = computed.load(Ordering::SeqCst);
    // Re-run: some partitions were evicted and recompute transparently.
    assert_eq!(rdd.count().unwrap(), 1000);
    let second = computed.load(Ordering::SeqCst);
    assert!(second > first, "eviction should force some recomputation");
    assert_eq!(rdd.collect().unwrap(), (0..1000i64).collect::<Vec<_>>());
}

#[test]
fn shuffle_output_loss_recovers_via_lineage() {
    let sc = IgniteContext::local(4);
    let rdd = sc
        .parallelize((0..200i64).collect())
        .map(|x| (x % 10, x))
        .reduce_by_key(4, |a, b| a + b);
    let before = rdd.collect_map().unwrap();
    // Wipe one map task's shuffle output, as a failed worker would.
    let shuffles_cleared = {
        // Find the shuffle id by re-running stage deps through a fresh
        // action after losing data — simplest: clear everything.
        sc.engine().shuffle.bucket_count()
    };
    assert!(shuffles_cleared > 0);
    // Lose all outputs of every shuffle (worst case).
    for shuffle_id in 0..10_000u64 {
        sc.engine().shuffle.clear_shuffle(shuffle_id);
    }
    let after = rdd.collect_map().unwrap();
    assert_eq!(before, after, "recomputed results must match");
}

#[test]
fn chaos_fault_injection_whole_pipeline() {
    let mut conf = IgniteConf::new();
    conf.set("ignite.fault.inject.seed", "99");
    conf.set("ignite.worker.slots", "4");
    conf.set("ignite.task.retries", "5");
    let sc = IgniteContext::with_conf(conf).unwrap();
    let total: i64 = sc
        .parallelize_with((1..=500i64).collect(), 16)
        .map(|x| x * 3)
        .filter(|x| x % 2 == 1)
        .reduce(|a, b| a + b)
        .unwrap();
    let expect: i64 = (1..=500i64).map(|x| x * 3).filter(|x| x % 2 == 1).sum();
    assert_eq!(total, expect, "retries must absorb chaos faults");
}

#[test]
fn union_sample_distinct_zip_with_index() {
    let sc = IgniteContext::local(4);
    let a = sc.parallelize((0..50i64).collect());
    let b = sc.parallelize((25..75i64).collect());
    let u = a.union(&b);
    assert_eq!(u.count().unwrap(), 100);
    let d = u.distinct(4);
    assert_eq!(d.count().unwrap(), 75);

    let sampled = sc.parallelize((0..10_000i64).collect()).sample(0.1, 7);
    let n = sampled.count().unwrap();
    assert!(n > 700 && n < 1300, "10% sample of 10k gave {n}");
    // Deterministic: same seed, same sample.
    assert_eq!(sampled.count().unwrap(), n);

    let idx = sc.parallelize_with(vec!["a", "b", "c", "d", "e"], 2).zip_with_index();
    let pairs = idx.collect().unwrap();
    assert_eq!(pairs.iter().map(|(_, i)| *i).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
}

#[test]
fn group_by_key_and_count_by_key() {
    let sc = IgniteContext::local(4);
    let pairs: Vec<(i64, i64)> = (0..60).map(|x| (x % 3, x)).collect();
    let grouped = sc.parallelize(pairs.clone()).group_by_key(4).collect_map().unwrap();
    assert_eq!(grouped.len(), 3);
    for (k, vs) in &grouped {
        assert_eq!(vs.len(), 20, "key {k}");
        for v in vs {
            assert_eq!(v % 3, *k);
        }
    }
    let counted = sc.parallelize(pairs).count_by_key(4).collect_map().unwrap();
    assert_eq!(counted[&0], 20);
    assert_eq!(counted[&1], 20);
    assert_eq!(counted[&2], 20);
}

#[test]
fn fold_take_first_mean() {
    let sc = IgniteContext::local(3);
    let rdd = sc.parallelize((1..=10i64).collect());
    assert_eq!(rdd.fold(0, |a, b| a + b).unwrap(), 55);
    assert_eq!(rdd.take(3).unwrap(), vec![1, 2, 3]);
    assert_eq!(rdd.first().unwrap(), 1);
    let means = sc.parallelize(vec![1.0f64, 2.0, 3.0, 4.0]);
    assert!((means.mean().unwrap() - 2.5).abs() < 1e-9);
    assert!((means.sum().unwrap() - 10.0).abs() < 1e-9);
}

#[test]
fn empty_rdd_edge_cases() {
    let sc = IgniteContext::local(2);
    let empty = sc.parallelize(Vec::<i64>::new());
    assert_eq!(empty.count().unwrap(), 0);
    assert!(empty.reduce(|a, b| a + b).is_err());
    assert!(empty.first().is_err());
    assert_eq!(empty.fold(0, |a, b| a + b).unwrap(), 0);
    assert_eq!(empty.collect().unwrap(), Vec::<i64>::new());
}

#[test]
fn rdd_feeding_parallel_closure_feeding_rdd() {
    // Full interop loop: RDD → closure (collectives) → RDD.
    let sc = IgniteContext::local(4);
    let squares = sc.parallelize((1..=16i64).collect()).map(|x| x * x).collect().unwrap();
    let squares = Arc::new(squares);
    let partials = sc
        .parallelize_func(move |world: &SparkComm| {
            let chunk = squares.len() / world.size();
            let r0 = world.rank() * chunk;
            let local: i64 = squares[r0..r0 + chunk].iter().sum();
            world.scan(local, |a, b| a + b).unwrap() // prefix sums
        })
        .execute(4)
        .unwrap();
    // Feed the per-rank prefix sums back into an RDD.
    let final_sum = sc.parallelize(partials.clone()).reduce(|a, b| a.max(b)).unwrap();
    let expect: i64 = (1..=16i64).map(|x| x * x).sum();
    assert_eq!(final_sum, expect);
    assert_eq!(*partials.last().unwrap(), expect);
}

#[test]
fn text_file_pipeline() {
    let path = "/tmp/mpignite-test-corpus.txt";
    std::fs::write(path, "one two\nthree\nfour five six\n").unwrap();
    let sc = IgniteContext::local(2);
    let words = sc
        .text_file(path)
        .unwrap()
        .flat_map(|l| l.split_whitespace().map(String::from).collect())
        .count()
        .unwrap();
    assert_eq!(words, 6);
    std::fs::remove_file(path).ok();
}

#[test]
fn join_and_cogroup() {
    let sc = IgniteContext::local(4);
    let users: Vec<(i64, String)> =
        vec![(1, "ada".into()), (2, "bob".into()), (3, "cyd".into())];
    let orders: Vec<(i64, i64)> = vec![(1, 100), (1, 101), (3, 300), (9, 900)];
    let joined = sc
        .parallelize(users.clone())
        .join(&sc.parallelize(orders.clone()), 4)
        .collect()
        .unwrap();
    let mut joined: Vec<(i64, (String, i64))> = joined;
    joined.sort_by_key(|(k, (_, o))| (*k, *o));
    assert_eq!(
        joined,
        vec![
            (1, ("ada".to_string(), 100)),
            (1, ("ada".to_string(), 101)),
            (3, ("cyd".to_string(), 300)),
        ],
        "inner join drops unmatched keys on both sides"
    );

    let cg = sc
        .parallelize(users)
        .cogroup(&sc.parallelize(orders), 4)
        .collect_map()
        .unwrap();
    assert_eq!(cg[&2], (vec!["bob".to_string()], vec![]));
    assert_eq!(cg[&9], (vec![], vec![900]));
    assert_eq!(cg[&1].1.len(), 2);
}

#[test]
fn sort_by_orders_globally() {
    let sc = IgniteContext::local(4);
    let data: Vec<i64> = vec![5, 3, 9, 1, 7, 2, 8, 4, 6, 0];
    let sorted = sc.parallelize(data).sort_by(|x| *x, 3).unwrap();
    assert_eq!(sorted.collect().unwrap(), (0..10i64).collect::<Vec<_>>());
    assert_eq!(sorted.num_partitions(), 3);
    // Descending via key transform.
    let desc = sc
        .parallelize(vec![1i64, 3, 2])
        .sort_by(|x| std::cmp::Reverse(*x), 2)
        .unwrap()
        .collect()
        .unwrap();
    assert_eq!(desc, vec![3, 2, 1]);
}
