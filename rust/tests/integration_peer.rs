//! Gang-scheduled peer sections, end to end on a real (in-process)
//! cluster:
//!
//! * a 3-iteration k-means peer section runs distributed across 2
//!   workers — ranks on *different workers* exchange centroids through
//!   an in-stage `all_reduce` (asserted via each worker's
//!   `cluster.worker.<id>.peer.bytes.sent` counter), with NO shuffle and
//!   NO driver round-trip per iteration — and the result matches the
//!   single-process closure path (`Rdd::map_partitions_peer`) exactly;
//! * killing a worker mid-iteration aborts and reschedules the WHOLE
//!   gang on the survivor with a bumped communicator generation —
//!   exactly one gang restart — and the job still converges to the
//!   fault-free result;
//! * a scripted `FaultInjector` rank failure takes the same gang-restart
//!   path, and seeded chaos mode (local engine) is absorbed by the
//!   gang retry machinery;
//! * consecutive gang restarts are spaced by the exponential backoff
//!   (`ignite.peer.gang.backoff.ms`, deterministic seeded jitter) — the
//!   wall clock of a double-restart collect is bounded below by the
//!   recomputed per-generation delays;
//! * all-or-nothing placement: a cluster with fewer slots than ranks
//!   rejects the gang up front.

use mpignite::apps;
use mpignite::cluster::Worker;
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use mpignite::rdd::PlanStageKind;
use std::sync::{Arc, Mutex, Once};
use std::time::{Duration, Instant};

/// Serializes the tests in this binary: they assert exact deltas of
/// process-global peer metrics, which interleaved tests would skew.
static SERIAL: Mutex<()> = Mutex::new(());

static OPS: Once = Once::new();

const K: usize = 3;
const ITERS: usize = 3;

fn register_ops() {
    OPS.call_once(|| {
        apps::register_kmeans_peer("peer.test.kmeans", K, ITERS);
        // Identical math, but slow enough that a worker can be killed
        // mid-iteration (the sleeps do not change the result).
        register_peer_op("peer.test.kmeans_slow", |comm, rows| {
            let points = apps::peer_points(&rows)?;
            let mut centroids = apps::kmeans_init(comm, &points, K)?;
            for _ in 0..ITERS {
                std::thread::sleep(Duration::from_millis(120));
                centroids = apps::kmeans_iteration(comm, &points, &centroids)?;
            }
            Ok(centroids.into_iter().map(Value::F64Vec).collect())
        });
        // Iterative reduction where the NEXT iteration's "compute" (a
        // deterministic value update) runs while the CURRENT iteration's
        // i_all_reduce is in flight — the overlap the non-blocking
        // collectives exist for.
        register_peer_op("peer.test.overlap_iterate", |comm, rows| {
            let mut local = rows.len() as f64 + comm.rank() as f64;
            let mut sums = Vec::with_capacity(ITERS);
            for _ in 0..ITERS {
                let fut = comm.i_all_reduce(local, |a, b| a + b)?;
                // Overlapped compute: mutate local state while the
                // collective on the PRE-update value is still running.
                local = local * 1.5 + 1.0;
                sums.push(Value::F64(fut.wait()?));
            }
            Ok(sums)
        });
        // Same math, blocking all_reduce — the bit-identity reference.
        register_peer_op("peer.test.blocking_iterate", |comm, rows| {
            let mut local = rows.len() as f64 + comm.rank() as f64;
            let mut sums = Vec::with_capacity(ITERS);
            for _ in 0..ITERS {
                let sum = comm.all_reduce(local, |a, b| a + b)?;
                local = local * 1.5 + 1.0;
                sums.push(Value::F64(sum));
            }
            Ok(sums)
        });
        // Splits the gang's communicator and rings a LARGE payload
        // through the DERIVED communicator only — the split protocol's
        // own messages are tiny, so the per-worker peer-byte assertions
        // below can only pass if derived contexts keep the peer flag.
        register_peer_op("peer.test.split_exchange", |comm, rows| {
            let sub = comm.split(0, comm.rank() as i64)?;
            let payload = vec![sub.rank() as f64; 2048]; // ~16 KiB encoded
            let next = (sub.rank() + 1) % sub.size();
            let prev = (sub.rank() + sub.size() - 1) % sub.size();
            sub.send(next, 9, Value::F64Vec(payload))?;
            let _: Value = sub.receive(prev as i64, 9)?;
            Ok(rows)
        });
    });
}

fn metric(name: &str) -> u64 {
    mpignite::metrics::global().counter(name).get()
}

fn conf() -> IgniteConf {
    let mut c = IgniteConf::new();
    c.set("ignite.worker.heartbeat.ms", "50");
    c.set("ignite.worker.timeout.ms", "600");
    // A gang whose sibling died must unblock its collectives well before
    // the peer-section deadline.
    c.set("ignite.comm.recv.timeout.ms", "3000");
    c
}

/// 24 2-D points around three well-separated centers, so k-means with
/// k=3 is stable; partition 0 (rank 0) holds one point per cluster among
/// its first K rows, making the broadcast initialization well-spread.
fn points() -> Vec<Value> {
    (0..24)
        .map(|i| {
            let center = match i % 3 {
                0 => (0.0, 0.0),
                1 => (10.0, 0.0),
                _ => (0.0, 10.0),
            };
            let jitter = 0.05 * i as f64;
            Value::F64Vec(vec![center.0 + jitter, center.1 - jitter])
        })
        .collect()
}

fn setup(c: &IgniteConf, n: usize) -> (IgniteContext, Vec<Arc<Worker>>) {
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..n).map(|_| Worker::start(c, master.address()).unwrap()).collect();
    master.wait_for_workers(n, Duration::from_secs(5)).unwrap();
    (sc, workers)
}

/// The single-process closure path over the same points — the reference
/// semantics every distributed run must reproduce bit-for-bit.
fn closure_reference() -> Vec<Value> {
    let sc = IgniteContext::local(2);
    sc.parallelize_with(points(), 2)
        .map_partitions_peer(|comm, rows| apps::kmeans_peer_step(comm, rows, K, ITERS))
        .unwrap()
        .collect()
        .unwrap()
}

fn wait_workers_drained(workers: &[Arc<Worker>]) {
    let deadline = Instant::now() + Duration::from_secs(3);
    loop {
        let buckets: usize = workers.iter().map(|w| w.engine().shuffle.bucket_count()).sum();
        if buckets == 0 {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "job.clear never drained the workers' peer buckets ({buckets} left)"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn kmeans_peer_section_runs_distributed_with_in_stage_allreduce() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = conf();
    let (sc, workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    let sent_before: Vec<u64> = workers.iter().map(|w| w.peer_bytes_sent()).collect();
    let shuffles_before = metric("cluster.shuffle.registrations");

    let got = sc.peer_rdd(points(), 2, "peer.test.kmeans").collect().unwrap();

    // Two ranks × K centroids, identical across ranks.
    assert_eq!(got.len(), 2 * K);
    assert_eq!(got[..K], got[K..], "gang members must agree on the centroids");

    // Ranks lived on DIFFERENT workers and exchanged centroid stats
    // through the in-stage all_reduce: both workers sent peer bytes.
    for (i, w) in workers.iter().enumerate() {
        let sent = w.peer_bytes_sent() - sent_before[i];
        assert!(sent > 0, "worker {} sent no peer-section bytes", w.worker_id);
    }
    // No per-iteration shuffle: the only map-output registrations are
    // the gang's own rank outputs (one per rank, not one per iteration).
    let registered = metric("cluster.shuffle.registrations") - shuffles_before;
    assert_eq!(registered, 2, "peer section registers one output per rank");

    // The distributed gang reproduces the closure fast path exactly.
    assert_eq!(got, closure_reference(), "distributed ≠ closure reference");

    // Job-end GC covers peer ids like shuffle ids.
    assert_eq!(master.shuffle_table_len(), 0, "job.clear pruned the peer outputs");
    wait_workers_drained(&workers);
    master.shutdown();
}

#[test]
fn split_traffic_inside_peer_section_keeps_byte_accounting() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = conf();
    let (sc, workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    let sent_before: Vec<u64> = workers.iter().map(|w| w.peer_bytes_sent()).collect();
    let got = sc.peer_rdd(points(), 2, "peer.test.split_exchange").collect().unwrap();
    assert_eq!(got.len(), points().len(), "split_exchange passes rows through");

    // Each rank lives on its own worker and rings ~16 KiB through the
    // communicator DERIVED by split(); the split protocol itself moves
    // <1 KiB. Both workers must therefore show multi-KiB peer-byte
    // deltas — which requires the derived context to keep the peer flag.
    for (i, w) in workers.iter().enumerate() {
        let sent = w.peer_bytes_sent() - sent_before[i];
        assert!(
            sent > 8_000,
            "worker {} sent only {sent} peer bytes: split dropped the peer flag",
            w.worker_id
        );
    }
    master.shutdown();
}

#[test]
fn i_all_reduce_overlaps_compute_inside_distributed_peer_section() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = conf();
    let (sc, _workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    // Overlapped lane: each rank starts the collective, advances its
    // local state while the reduction is in flight, then waits.
    let overlapped_before = metric("comm.collectives.overlapped");
    let got = sc.peer_rdd(points(), 2, "peer.test.overlap_iterate").collect().unwrap();
    let overlapped = metric("comm.collectives.overlapped") - overlapped_before;
    assert!(
        overlapped >= ITERS as u64,
        "each iteration must start a non-blocking collective, got {overlapped}"
    );

    // Blocking reference lane on the same cluster: the overlap changes
    // WHEN the reduction runs relative to the update, never the values.
    let want = sc.peer_rdd(points(), 2, "peer.test.blocking_iterate").collect().unwrap();
    assert_eq!(got, want, "overlapped collectives must be bit-identical to blocking");

    // Oracle: 2 ranks × 12 rows each, locals 12.0 and 13.0, tripling
    // through local = local*1.5 + 1 each iteration.
    let (mut l0, mut l1) = (12.0f64, 13.0f64);
    let mut oracle = Vec::new();
    for _ in 0..ITERS {
        oracle.push(Value::F64(l0 + l1));
        l0 = l0 * 1.5 + 1.0;
        l1 = l1 * 1.5 + 1.0;
    }
    // Both ranks emit the same per-iteration sums.
    let expect: Vec<Value> =
        oracle.iter().cloned().chain(oracle.iter().cloned()).collect();
    assert_eq!(got, expect, "per-iteration global sums diverged from the oracle");
    master.shutdown();
}

#[test]
fn worker_loss_mid_iteration_restarts_gang_once_and_converges() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = conf();
    let (sc, workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    let restarts_before = metric("peer.gang.restarts");
    let job_retries_before = metric("cluster.plan.jobs.retried");

    // Launch in the background; the gang spends >= 360ms in its
    // sleep-per-iteration loop, so a kill at 250ms lands mid-iteration.
    let job = sc.peer_rdd(points(), 2, "peer.test.kmeans_slow");
    let driver = std::thread::spawn(move || job.collect());
    std::thread::sleep(Duration::from_millis(250));
    workers[1].kill();

    let got = driver.join().expect("driver thread").unwrap();

    assert_eq!(
        metric("peer.gang.restarts") - restarts_before,
        1,
        "exactly one gang restart (fresh communicator generation)"
    );
    assert_eq!(
        metric("cluster.plan.jobs.retried") - job_retries_before,
        0,
        "the gang restarted inside the stage; the job itself never retried"
    );
    // The restarted gang (both ranks on the survivor) still converges to
    // the fault-free result.
    assert_eq!(got, closure_reference(), "post-restart result diverged");
    assert_eq!(master.live_workers().len(), 1);
    master.shutdown();
}

#[test]
fn injected_rank_fault_restarts_gang_on_bumped_generation() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = conf();
    let (sc, workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    let job = sc.peer_rdd(points(), 2, "peer.test.kmeans");
    let peer_id = job
        .plan()
        .stages()
        .iter()
        .find(|s| s.kind == PlanStageKind::Peer)
        .expect("plan has a peer stage")
        .id;
    // Kill rank 0's generation-0 attempt on whichever worker hosts it
    // (round-robin places rank 0 on the first-registered worker). The
    // FaultInjector hook sits on the peer-task path like any task's.
    workers[0].engine().fault.fail_task(peer_id, 0, 0);

    let restarts_before = metric("peer.gang.restarts");
    let got = job.collect().unwrap();

    assert_eq!(
        metric("peer.gang.restarts") - restarts_before,
        1,
        "the injected rank fault must abort and restart the whole gang"
    );
    assert_eq!(got, closure_reference(), "post-restart result diverged");
    master.shutdown();
}

#[test]
fn gang_restarts_are_spaced_by_deterministic_backoff() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = {
        let mut c = conf();
        // A base large enough that the two backoff sleeps dominate the
        // (fast) k-means job in the wall-clock assertion below.
        c.set("ignite.peer.gang.backoff.ms", "150");
        c
    };
    let (sc, workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    let job = sc.peer_rdd(points(), 2, "peer.test.kmeans");
    let peer_id = job
        .plan()
        .stages()
        .iter()
        .find(|s| s.kind == PlanStageKind::Peer)
        .expect("plan has a peer stage")
        .id;
    // Rank 0's first TWO generations die: the collect traverses the
    // generation-1 and generation-2 backoff sleeps before the third
    // attempt (the last within the default budget) wins.
    workers[0].engine().fault.fail_task(peer_id, 0, 0);
    workers[0].engine().fault.fail_task(peer_id, 0, 1);

    // The delay is a pure function of (conf, peer_id, generation): the
    // test recomputes the exact spacing the master must have slept.
    let delay = |g| mpignite::peer::gang_backoff_delay(sc.conf(), peer_id, g);
    let spacing = delay(1) + delay(2);
    // Seeded jitter stays within [exp/2, exp] of the doubling base.
    assert!(delay(1) >= Duration::from_millis(75) && delay(1) <= Duration::from_millis(150));
    assert!(delay(2) >= Duration::from_millis(150) && delay(2) <= Duration::from_millis(300));
    let (once, again) = (delay(1), delay(1));
    assert_eq!(once, again, "jitter must be deterministic per (peer, generation)");

    let restarts_before = metric("peer.gang.restarts");
    let t0 = Instant::now();
    let got = job.collect().unwrap();
    let elapsed = t0.elapsed();

    assert_eq!(
        metric("peer.gang.restarts") - restarts_before,
        2,
        "both scripted rank faults must each restart the gang"
    );
    assert!(
        elapsed >= spacing,
        "restarts must be spaced by the configured backoff: ran {elapsed:?}, \
         deterministic spacing is {spacing:?}"
    );
    assert_eq!(got, closure_reference(), "post-restart result diverged");
    master.shutdown();
}

#[test]
fn peer_sections_complete_under_seeded_chaos_locally() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    // Chaos mode: every task's first attempt — gang ranks included —
    // fails with 5% probability from a deterministic seed; gang retries
    // (bumped attempt numbers are spared by chaos) absorb all of it.
    let mut c = IgniteConf::new();
    c.set("ignite.master", "local[4]");
    c.set("ignite.worker.slots", "4");
    c.set("ignite.fault.inject.seed", "1234");
    c.set("ignite.comm.recv.timeout.ms", "1000");
    let sc = IgniteContext::with_conf(c).unwrap();
    assert!(sc.engine().fault.is_active());
    let got = sc.peer_rdd(points(), 4, "peer.test.kmeans").collect().unwrap();

    let plain = IgniteContext::local(4);
    let want = plain.peer_rdd(points(), 4, "peer.test.kmeans").collect().unwrap();
    assert_eq!(got, want, "chaos must not change the converged result");
}

#[test]
fn gang_placement_is_all_or_nothing() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = {
        let mut c = conf();
        c.set("ignite.worker.slots", "1");
        c
    };
    let (sc, _workers) = setup(&c, 1);
    // 3 ranks, 1 slot: the gang must be rejected up front, not deadlock.
    let err = sc.peer_rdd(points(), 3, "peer.test.kmeans").collect().unwrap_err();
    assert!(err.to_string().contains("gang slots"), "got: {err}");
    sc.master().unwrap().shutdown();
}
