//! Cluster integration: multi-worker jobs over real sockets, worker loss
//! mid-job with the paper's p2p→relay recovery, rank placement, and
//! back-to-back job isolation.

use mpignite::cluster::{Master, Worker};
use mpignite::closure::register_parallel_fn;
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use std::sync::Arc;
use std::time::Duration;

/// These tests rely on heartbeat timing (hundreds of ms); running five
/// clusters concurrently in one test process oversubscribes the CPU and
/// turns timing assumptions into flakes. Serialize them.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn conf() -> IgniteConf {
    let mut c = IgniteConf::new();
    c.set("ignite.worker.heartbeat.ms", "50");
    c.set("ignite.worker.timeout.ms", "600");
    c.set("ignite.comm.recv.timeout.ms", "8000");
    c
}

fn setup(n: usize, c: &IgniteConf) -> (Arc<Master>, Vec<Arc<Worker>>) {
    let master = Master::start(c, 0).unwrap();
    let workers = (0..n).map(|_| Worker::start(c, master.address()).unwrap()).collect();
    master.wait_for_workers(n, Duration::from_secs(5)).unwrap();
    (master, workers)
}

#[test]
fn wide_job_spans_many_workers() {
    let _serial = lock();
    register_parallel_fn("ic.wide", |comm, _| {
        // Every rank exchanges with its mirror; then a global barrier.
        let other = comm.size() - 1 - comm.rank();
        let got: i64 = if other == comm.rank() {
            comm.rank() as i64
        } else {
            comm.sendrecv(other, other as i64, 0, comm.rank() as i64)?
        };
        comm.barrier()?;
        Ok(Value::I64(got))
    });
    let c = conf();
    let (master, _workers) = setup(4, &c);
    let out = master.execute_named("ic.wide", 12, Value::Unit).unwrap();
    for (rank, v) in out.iter().enumerate() {
        assert_eq!(*v, Value::I64((12 - 1 - rank) as i64));
    }
    master.shutdown();
}

#[test]
fn worker_killed_mid_job_recovers_over_relay() {
    let _serial = lock();
    mpignite::util::init_logger();
    // Rank 0 stalls until a deadline so the job is in flight when the
    // worker dies; the master detects the loss and re-runs on survivors
    // with the relay fallback.
    register_parallel_fn("ic.slow_allreduce", |comm, arg| {
        let delay_ms = match arg {
            Value::I64(d) => *d,
            _ => 0,
        };
        if comm.rank() == 0 {
            std::thread::sleep(Duration::from_millis(delay_ms as u64));
        }
        let v = comm.all_reduce(1i64, |a, b| a + b)?;
        Ok(Value::I64(v))
    });
    let c = conf();
    let (master, workers) = setup(3, &c);

    // Kill a worker shortly after the job starts.
    let victim = workers[1].clone();
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(150));
        victim.kill();
    });
    let before = mpignite::metrics::global().counter("cluster.jobs.recovered").get();
    let out = master
        .execute_named("ic.slow_allreduce", 6, Value::I64(1500))
        .unwrap();
    killer.join().unwrap();
    assert_eq!(out, vec![Value::I64(6); 6], "job completed after recovery");
    let after = mpignite::metrics::global().counter("cluster.jobs.recovered").get();
    assert!(after > before, "recovery path must have been taken");
    master.shutdown();
}

#[test]
fn rank_tables_route_correctly_with_uneven_workers() {
    let _serial = lock();
    // More ranks than workers: round-robin placement, cross-worker ring.
    register_parallel_fn("ic.ring", |world, _| {
        let (rank, size) = (world.rank(), world.size());
        let token = if rank == 0 {
            world.send(1 % size, 0, 99i64)?;
            world.receive::<i64>((size - 1) as i64, 0)?
        } else {
            let t = world.receive::<i64>((rank - 1) as i64, 0)?;
            world.send((rank + 1) % size, 0, t)?;
            t
        };
        Ok(Value::I64(token))
    });
    let c = conf();
    let (master, _workers) = setup(2, &c);
    for n in [2usize, 5, 9] {
        let out = master.execute_named("ic.ring", n, Value::Unit).unwrap();
        assert_eq!(out, vec![Value::I64(99); n], "ring of {n}");
    }
    master.shutdown();
}

#[test]
fn errors_in_one_rank_fail_the_job_with_context() {
    let _serial = lock();
    register_parallel_fn("ic.partial_fail", |comm, _| {
        if comm.rank() == 2 {
            return Err(IgniteError::Invalid("rank 2 business logic error".into()));
        }
        Ok(Value::Unit)
    });
    let c = conf();
    let (master, _workers) = setup(2, &c);
    let err = master.execute_named("ic.partial_fail", 4, Value::Unit).unwrap_err();
    assert!(err.to_string().contains("rank 2"), "got: {err}");
    master.shutdown();

    // Note: ranks 0,1,3 may block in collectives with rank 2 gone — this
    // function has none, so threads exit cleanly.
}

#[test]
fn many_sequential_jobs_contexts_isolated() {
    let _serial = lock();
    register_parallel_fn("ic.seq", |comm, arg| {
        let round = match arg {
            Value::I64(r) => *r,
            _ => 0,
        };
        // Deliberately leave an unreceived message dangling each round:
        // context isolation must prevent it leaking into the next job.
        if comm.rank() == 0 {
            comm.send(1, 5, round * 100)?;
            comm.send(1, 6, -1i64)?; // never received
        }
        let got = if comm.rank() == 1 {
            comm.receive::<i64>(0, 5)?
        } else {
            0
        };
        let sum = comm.all_reduce(got, |a, b| a + b)?;
        Ok(Value::I64(sum))
    });
    let c = conf();
    let (master, _workers) = setup(2, &c);
    for round in 0..5i64 {
        let out = master.execute_named("ic.seq", 3, Value::I64(round)).unwrap();
        assert_eq!(out, vec![Value::I64(round * 100); 3], "round {round}");
    }
    master.shutdown();
}
