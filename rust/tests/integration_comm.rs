//! Integration tests for the comm layer through the public API: the four
//! paper listings, collectives composed with splits, cross-communicator
//! isolation, and the relay/p2p transports over real TCP.

use mpignite::cluster::{Master, Worker};
use mpignite::comm::{run_local_world, CollectiveAlgo, ANY_SOURCE};
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use std::time::Duration;

#[test]
fn listing1_matvec_closure() {
    let sc = IgniteContext::local(8);
    let mat = vec![vec![1i64, 2, 3], vec![4, 5, 6], vec![7, 8, 9]];
    let v = vec![1i64, 2, 3];
    let res: i64 = sc
        .parallelize_func(move |world: &SparkComm| {
            let rank = world.rank();
            if rank < mat.len() {
                mat[rank].iter().zip(&v).map(|(a, b)| a * b).sum()
            } else {
                0
            }
        })
        .execute(8)
        .unwrap()
        .into_iter()
        .sum();
    assert_eq!(res, 96);
}

#[test]
fn listing2_ring_many_sizes() {
    for n in [2usize, 3, 16, 33] {
        let out = run_local_world(n, move |world| {
            let (rank, size) = (world.rank(), world.size());
            if rank == 0 {
                world.send((rank + 1) % size, 0, 7i64)?;
                world.receive::<i64>((size - 1) as i64, 0)
            } else {
                let t = world.receive::<i64>((rank - 1) as i64, 0)?;
                world.send((rank + 1) % size, 0, t)?;
                Ok(t)
            }
        })
        .unwrap();
        assert!(out.iter().all(|&t| t == 7), "n={n}");
    }
}

#[test]
fn listing3_nonblocking_future_chain() {
    let out = run_local_world(10, |world| {
        let (size, rank) = (world.size(), world.rank());
        let half = size / 2;
        if rank < half {
            world.send(rank + half, 0, rank as i64)?;
            let f = world.receive_async::<bool>((rank + half) as i64, 0)?;
            assert!(!f.is_ready() || true); // may race; just exercises API
            f.wait_timeout(Duration::from_secs(5)).map(Some)
        } else {
            let r = world.receive::<i64>((rank - half) as i64, 0)?;
            world.send(rank - half, 0, r % 2 == 0)?;
            Ok(None)
        }
    })
    .unwrap();
    for (rank, v) in out.iter().enumerate().take(5) {
        assert_eq!(*v, Some(rank % 2 == 0));
    }
}

#[test]
fn listing4_full_grid() {
    let out = run_local_world(9, |world| {
        let wr = world.rank();
        let row = world.split((wr / 3) as i64, wr as i64)?;
        let col = world.split((wr % 3) as i64, wr as i64)?;
        let a = (wr + 1) as i64;
        if row.rank() == row.size() - 1 {
            row.send(col.rank(), 0, 1 + col.rank() as i64)?;
        }
        let x_row = if row.rank() == col.rank() {
            Some(row.receive::<i64>((row.size() - 1) as i64, 0)?)
        } else {
            None
        };
        let x = match x_row {
            Some(x) => col.broadcast(col.rank(), Some(x))?,
            None => col.broadcast::<i64>(row.rank(), None)?,
        };
        row.all_reduce(a * x, |p, q| p + q)
    })
    .unwrap();
    assert_eq!(out[0], 14);
    assert_eq!(out[3], 32);
    assert_eq!(out[6], 50);
}

#[test]
fn collectives_inside_subcommunicators() {
    // allReduce within each split half must not leak across halves.
    let out = run_local_world(8, |world| {
        let half = world.split((world.rank() / 4) as i64, world.rank() as i64)?;
        half.all_reduce(world.rank() as i64, |a, b| a + b)
    })
    .unwrap();
    for r in 0..4 {
        assert_eq!(out[r], 0 + 1 + 2 + 3);
    }
    for r in 4..8 {
        assert_eq!(out[r], 4 + 5 + 6 + 7);
    }
}

#[test]
fn wildcard_receive_across_collective_traffic() {
    // User ANY_SOURCE receives must not capture internal collective
    // messages (negative tags).
    let out = run_local_world(4, |world| {
        if world.rank() != 0 {
            world.send(0, 9, world.rank() as i64)?;
        }
        let b = world.broadcast(0, if world.rank() == 0 { Some(1i64) } else { None })?;
        assert_eq!(b, 1);
        if world.rank() == 0 {
            let mut sum = 0;
            for _ in 0..3 {
                sum += world.receive::<i64>(ANY_SOURCE, 9)?;
            }
            Ok(sum)
        } else {
            Ok(0)
        }
    })
    .unwrap();
    assert_eq!(out[0], 6);
}

#[test]
fn all_algorithms_agree_on_same_input() {
    for n in [3usize, 8] {
        let mut answers = Vec::new();
        for algo in [CollectiveAlgo::Linear, CollectiveAlgo::Tree, CollectiveAlgo::Ring] {
            let out = run_local_world(n, move |world| {
                world.all_reduce_with(algo, (world.rank() * world.rank()) as i64, |a, b| a + b)
            })
            .unwrap();
            answers.push(out[0]);
        }
        assert!(answers.windows(2).all(|w| w[0] == w[1]), "algos disagree: {answers:?}");
    }
}

#[test]
fn tcp_cluster_split_and_collectives() {
    // The full Listing-4 communication pattern over real worker processes
    // (in-process envs, real sockets).
    mpignite::closure::register_parallel_fn("it.comm.grid", |world, _| {
        let wr = world.rank();
        let row = world.split((wr / 2) as i64, wr as i64)?;
        let col = world.split((wr % 2) as i64, wr as i64)?;
        let r = row.all_reduce((wr + 1) as i64, |a, b| a + b)?;
        let c = col.all_reduce((wr + 1) as i64, |a, b| a + b)?;
        Ok(Value::I64Vec(vec![r, c]))
    });
    let mut conf = IgniteConf::new();
    conf.set("ignite.worker.heartbeat.ms", "50");
    let master = Master::start(&conf, 0).unwrap();
    let _w1 = Worker::start(&conf, master.address()).unwrap();
    let _w2 = Worker::start(&conf, master.address()).unwrap();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();
    let out = master.execute_named("it.comm.grid", 4, Value::Unit).unwrap();
    // Grid ranks: 0 1 / 2 3 (value rank+1). Row sums: {1+2, 3+4}; col {1+3, 2+4}.
    assert_eq!(out[0], Value::I64Vec(vec![3, 4]));
    assert_eq!(out[1], Value::I64Vec(vec![3, 6]));
    assert_eq!(out[2], Value::I64Vec(vec![7, 4]));
    assert_eq!(out[3], Value::I64Vec(vec![7, 6]));
    master.shutdown();
}

#[test]
fn relay_and_p2p_give_identical_results() {
    mpignite::closure::register_parallel_fn("it.comm.exchange", |world, _| {
        let other = world.size() - 1 - world.rank();
        if other == world.rank() {
            return Ok(Value::I64(world.rank() as i64));
        }
        let got: i64 = world.sendrecv(other, other as i64, 4, world.rank() as i64)?;
        Ok(Value::I64(got))
    });
    let mut results = Vec::new();
    for mode in ["p2p", "relay"] {
        let mut conf = IgniteConf::new();
        conf.set("ignite.comm.mode", mode);
        conf.set("ignite.worker.heartbeat.ms", "50");
        let master = Master::start(&conf, 0).unwrap();
        let _w1 = Worker::start(&conf, master.address()).unwrap();
        let _w2 = Worker::start(&conf, master.address()).unwrap();
        master.wait_for_workers(2, Duration::from_secs(5)).unwrap();
        let out = master.execute_named("it.comm.exchange", 4, Value::Unit).unwrap();
        results.push(out);
        master.shutdown();
    }
    assert_eq!(results[0], results[1], "transport mode must not change semantics");
    assert_eq!(results[0], vec![Value::I64(3), Value::I64(2), Value::I64(1), Value::I64(0)]);
}

#[test]
fn stress_many_small_messages() {
    // 4 ranks, all-to-all bursts with tag fan-out; checks matching under
    // concurrency and receiver-side buffering depth.
    let per_pair = 50;
    let out = run_local_world(4, move |world| {
        let me = world.rank();
        for dst in 0..world.size() {
            if dst != me {
                for i in 0..per_pair {
                    world.send(dst, (i % 5) as i64, (me * 1000 + i) as i64)?;
                }
            }
        }
        let mut received = 0usize;
        let mut sum = 0i64;
        for src in 0..world.size() {
            if src != me {
                for i in 0..per_pair {
                    let v: i64 = world.receive(src as i64, (i % 5) as i64)?;
                    assert_eq!(v, (src * 1000 + i) as i64, "FIFO per (src, tag)");
                    sum += v;
                    received += 1;
                }
            }
        }
        assert_eq!(received, 3 * per_pair);
        Ok(sum)
    })
    .unwrap();
    assert_eq!(out.len(), 4);
}

#[test]
fn probe_sees_buffered_without_consuming() {
    let out = run_local_world(2, |comm| {
        if comm.rank() == 0 {
            comm.send(1, 4, 77i64)?;
            Ok(None)
        } else {
            // Wait for the message to be buffered.
            let deadline = std::time::Instant::now() + Duration::from_secs(2);
            while comm.probe(0, 4)?.is_none() {
                assert!(std::time::Instant::now() < deadline, "probe never saw message");
                std::thread::yield_now();
            }
            let hit = comm.probe(0, 4)?;
            assert_eq!(hit, Some((0, 4)));
            // Probing did not consume: receive still works.
            let v: i64 = comm.receive(0, 4)?;
            assert_eq!(comm.probe(0, 4)?, None, "consumed after receive");
            Ok(Some(v))
        }
    })
    .unwrap();
    assert_eq!(out[1], Some(77));
}

#[test]
fn dup_isolates_tag_space() {
    // Same ranks, two communicators: a library using the dup cannot steal
    // the application's messages even with identical (src, tag).
    let out = run_local_world(2, |comm| {
        let lib = comm.dup()?;
        assert_eq!(lib.rank(), comm.rank());
        assert_eq!(lib.size(), comm.size());
        assert_ne!(lib.context_id(), comm.context_id());
        if comm.rank() == 0 {
            comm.send(1, 0, 1i64)?;
            lib.send(1, 0, 2i64)?;
            Ok((0, 0))
        } else {
            // Receive library message first — must NOT get the app one.
            let from_lib: i64 = lib.receive(0, 0)?;
            let from_app: i64 = comm.receive(0, 0)?;
            Ok((from_app, from_lib))
        }
    })
    .unwrap();
    assert_eq!(out[1], (1, 2));
}

#[test]
fn all_to_all_transposes() {
    let n = 4;
    let out = run_local_world(n, move |comm| {
        // data[i] = rank*10 + i  →  received[src] = src*10 + my_rank.
        let data: Vec<i64> = (0..n).map(|i| (comm.rank() * 10 + i) as i64).collect();
        comm.all_to_all(data)
    })
    .unwrap();
    for (rank, received) in out.iter().enumerate() {
        let expect: Vec<i64> = (0..n).map(|src| (src * 10 + rank) as i64).collect();
        assert_eq!(*received, expect, "rank {rank}");
    }
}

#[test]
fn all_to_all_wrong_count_errors() {
    let err = run_local_world(3, |comm| {
        comm.all_to_all(vec![1i64])?;
        Ok(())
    })
    .unwrap_err();
    assert!(err.to_string().contains("needs 3 items"));
}
