//! Distributed plan execution: a word-count job built entirely from
//! named/built-in plan operators (`flat_map` → `reduce_by_key` →
//! `collect`) runs end-to-end on a real cluster — map *tasks* execute on
//! worker processes (asserted via per-worker task-execution counters, not
//! just remote shuffle fetches), reduce tasks pull buckets over
//! `shuffle.fetch`, results match driver-local execution exactly, and the
//! piggybacked `shuffle.clear` leaves the master's map-output table empty.

use mpignite::closure::register_op;
use mpignite::cluster::{worker_task_counter, Worker};
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use mpignite::rdd::AggSpec;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

fn register_wordcount_ops() {
    register_op("wc.split", |v| match v {
        Value::Str(line) => Ok(Value::List(
            line.split_whitespace().map(|w| Value::Str(w.to_string())).collect(),
        )),
        other => {
            Err(IgniteError::Invalid(format!("wc.split wants str, got {}", other.type_name())))
        }
    });
    register_op("wc.pair", |v| Ok(Value::List(vec![v, Value::I64(1)])));
}

fn corpus_lines() -> Vec<Value> {
    [
        "apple pear apple plum",
        "pear pear kiwi",
        "apple plum plum kiwi apple",
        "kiwi apple fig",
    ]
    .iter()
    .map(|l| Value::Str(l.to_string()))
    .collect()
}

fn counts_of(rows: Vec<Value>) -> HashMap<String, i64> {
    let mut out = HashMap::new();
    for row in rows {
        match row {
            Value::List(l) if l.len() == 2 => match (&l[0], &l[1]) {
                (Value::Str(w), Value::I64(n)) => {
                    assert!(out.insert(w.clone(), *n).is_none(), "duplicate key {w}");
                }
                other => panic!("bad pair {other:?}"),
            },
            other => panic!("bad row {other:?}"),
        }
    }
    out
}

fn conf() -> IgniteConf {
    let mut c = IgniteConf::new();
    c.set("ignite.worker.heartbeat.ms", "50");
    c.set("ignite.worker.timeout.ms", "2000");
    c
}

#[test]
fn plan_wordcount_runs_map_tasks_on_workers() {
    register_wordcount_ops();
    let c = conf();
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    let tasks_before: Vec<u64> = workers.iter().map(|w| w.tasks_executed()).collect();
    let fetches_before = mpignite::metrics::global().counter("shuffle.remote.fetches").get();

    let job = sc
        .parallelize_values_with(corpus_lines(), 4)
        .flat_map_named("wc.split")
        .map_named("wc.pair")
        .reduce_by_key(2, AggSpec::SumI64);
    let got = counts_of(job.collect().unwrap());

    // Every worker actually executed tasks (4 map + 2 reduce tasks are
    // placed round-robin over 2 workers, so each gets some of both).
    for (i, w) in workers.iter().enumerate() {
        let ran = w.tasks_executed() - tasks_before[i];
        assert!(ran > 0, "worker {} executed no tasks", w.worker_id);
        assert_eq!(
            ran,
            mpignite::metrics::global().counter(&worker_task_counter(w.worker_id)).get()
                - tasks_before[i],
            "Worker::tasks_executed reads the per-worker metric"
        );
    }
    // All 6 stage tasks (4 map + 2 reduce) ran on workers, not the driver.
    let total_ran: u64 = workers
        .iter()
        .enumerate()
        .map(|(i, w)| w.tasks_executed() - tasks_before[i])
        .sum();
    assert!(total_ran >= 6, "expected >= 6 worker-side tasks, got {total_ran}");
    // Reduce tasks pulled at least some buckets from the *other* worker.
    let fetched =
        mpignite::metrics::global().counter("shuffle.remote.fetches").get() - fetches_before;
    assert!(fetched >= 2, "reduce tasks must fetch remote buckets, got {fetched}");

    // Results identical to driver-local (closure-fast-path-equivalent) mode.
    let sc_local = IgniteContext::local(4);
    let want = counts_of(
        sc_local
            .parallelize_values_with(corpus_lines(), 4)
            .flat_map_named("wc.split")
            .map_named("wc.pair")
            .reduce_by_key(2, AggSpec::SumI64)
            .collect()
            .unwrap(),
    );
    assert_eq!(got, want, "distributed result matches local mode");
    assert_eq!(got["apple"], 5);
    assert_eq!(got["fig"], 1);
    assert_eq!(got.len(), 5);

    // Map-output GC piggybacked on job completion: the master's shuffle
    // location table must be empty, and the workers' local buckets (the
    // fan-out half of shuffle.clear) drain shortly after. The worker side
    // is polled briefly because the fan-out is a one-way send. (Shipped
    // task batches run without speculation, so no duplicate task can
    // finish after the clear and re-register.)
    let deadline = std::time::Instant::now() + Duration::from_secs(3);
    loop {
        let table = master.shuffle_table_len();
        let resident: usize = workers.iter().map(|w| w.engine().shuffle.bucket_count()).sum();
        if table == 0 && resident == 0 {
            break;
        }
        assert!(
            std::time::Instant::now() < deadline,
            "shuffle.clear incomplete: {table} table entries, {resident} worker buckets left"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(master.shuffle_table_len(), 0, "job.clear pruned the map-output table");
    assert_eq!(master.broadcast_table_len(), 0, "job.clear covers the broadcast table too");

    master.shutdown();
}

#[test]
fn plan_collect_falls_back_to_local_without_workers() {
    register_wordcount_ops();
    let sc = IgniteContext::cluster_driver(conf(), 0).unwrap();
    let got = counts_of(
        sc.parallelize_values_with(corpus_lines(), 4)
            .flat_map_named("wc.split")
            .map_named("wc.pair")
            .reduce_by_key(2, AggSpec::SumI64)
            .collect()
            .unwrap(),
    );
    assert_eq!(got["apple"], 5);
    sc.master().unwrap().shutdown();
}
