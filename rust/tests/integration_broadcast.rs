//! Cluster broadcast plane, end to end on a real (in-process) cluster:
//!
//! * a multi-stage plan job over a parallelized source ships the
//!   source's encoded bytes to each worker **at most once** (asserted
//!   via the `broadcast.bytes.fetched.{peer,master}` metrics — the
//!   acceptance criterion of the broadcast-plane issue);
//! * workers fetch peer-first: the second worker to assemble a value
//!   pulls every block from the first, not from the master;
//! * killing the peer that holds the only worker replica mid-fetch
//!   falls back to the master/driver copy block by block, and jobs
//!   still complete on the survivors;
//! * job-end cleanup is ONE `job.clear` covering both planes: after a
//!   plan job — successful or failed — the master's shuffle *and*
//!   broadcast tables are empty and the workers hold no buckets and no
//!   broadcast blocks.

use mpignite::closure::register_op;
use mpignite::cluster::Worker;
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use mpignite::rdd::AggSpec;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Serializes the tests in this binary: they assert exact deltas of
/// process-global broadcast metrics, which interleaved tests would skew.
static SERIAL: Mutex<()> = Mutex::new(());

fn metric(name: &str) -> u64 {
    mpignite::metrics::global().counter(name).get()
}

fn conf() -> IgniteConf {
    let mut c = IgniteConf::new();
    c.set("ignite.worker.heartbeat.ms", "50");
    c.set("ignite.worker.timeout.ms", "2000");
    c.set("ignite.broadcast.block.bytes", "64"); // force multi-block values
    c.set("ignite.broadcast.auto.min.bytes", "1"); // every source ships by reference
    c
}

fn register_ops() {
    register_op("bc.pair", |v| Ok(Value::List(vec![v, Value::I64(1)])));
}

fn source_rows() -> Vec<Value> {
    (0..48i64).map(|x| Value::Str(format!("word-{:02}", x % 7))).collect()
}

fn counts_of(rows: Vec<Value>) -> HashMap<String, i64> {
    let mut out = HashMap::new();
    for row in rows {
        match row {
            Value::List(l) if l.len() == 2 => match (&l[0], &l[1]) {
                (Value::Str(w), Value::I64(n)) => {
                    out.insert(w.clone(), *n);
                }
                other => panic!("bad pair {other:?}"),
            },
            other => panic!("bad row {other:?}"),
        }
    }
    out
}

fn setup(c: &IgniteConf, n: usize) -> (IgniteContext, Vec<Arc<Worker>>) {
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..n).map(|_| Worker::start(c, master.address()).unwrap()).collect();
    master.wait_for_workers(n, Duration::from_secs(5)).unwrap();
    (sc, workers)
}

/// Poll until every worker holds zero shuffle buckets and zero broadcast
/// state (the `job.clear` fan-out is a one-way send, so it lands shortly
/// after the job returns).
fn wait_workers_drained(workers: &[Arc<Worker>], what: &str) {
    let deadline = Instant::now() + Duration::from_secs(3);
    loop {
        let buckets: usize = workers.iter().map(|w| w.engine().shuffle.bucket_count()).sum();
        let values: usize = workers.iter().map(|w| w.engine().broadcast.value_count()).sum();
        let blocks: usize = workers.iter().map(|w| w.engine().broadcast.block_count()).sum();
        if buckets == 0 && values == 0 && blocks == 0 {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "{what}: cleanup incomplete ({buckets} buckets, {values} values, {blocks} blocks left)"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn multi_stage_plan_ships_source_bytes_once_per_worker() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = conf();
    let (sc, workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    let src = sc.parallelize_values_with(source_rows(), 4);
    let src_encoded = match src.plan() {
        PlanSpec::Source { partitions } => mpignite::ser::to_bytes(partitions).len() as u64,
        other => panic!("expected Source, got {other:?}"),
    };
    // Two chained shuffles → three stages, all shipped over task.run.
    let job = src
        .map_named("bc.pair")
        .reduce_by_key(3, AggSpec::SumI64)
        .reduce_by_key(2, AggSpec::First);

    let fetched_before =
        metric("broadcast.bytes.fetched.peer") + metric("broadcast.bytes.fetched.master");
    let rewritten_before = metric("cluster.broadcast.sources.rewritten");

    let got = counts_of(job.collect().unwrap());

    // The source was shipped by reference, not inlined per stage.
    assert!(
        metric("cluster.broadcast.sources.rewritten") > rewritten_before,
        "auto.min.bytes=1 must rewrite the source into a SourceRef"
    );
    // THE acceptance criterion: across a three-stage job, each of the 2
    // workers pulled the source's encoded bytes over its wire exactly
    // once — not once per stage, not once per task.
    let fetched =
        metric("broadcast.bytes.fetched.peer") + metric("broadcast.bytes.fetched.master")
            - fetched_before;
    assert_eq!(
        fetched,
        2 * src_encoded,
        "each worker's wire must carry the source exactly once (source = {src_encoded} B)"
    );

    // Results identical to driver-local execution of the same pipeline.
    let sc_local = IgniteContext::local(4);
    let want = counts_of(
        sc_local
            .parallelize_values_with(source_rows(), 4)
            .map_named("bc.pair")
            .reduce_by_key(3, AggSpec::SumI64)
            .reduce_by_key(2, AggSpec::First)
            .collect()
            .unwrap(),
    );
    assert_eq!(got, want, "broadcast-source result matches inline-source local run");
    assert_eq!(got.len(), 7);

    // Combined job-end GC: both master tables empty, workers drained.
    assert_eq!(master.shuffle_table_len(), 0, "job.clear pruned the map-output table");
    assert_eq!(master.broadcast_table_len(), 0, "job.clear pruned the broadcast table");
    wait_workers_drained(&workers, "successful job");
    master.shutdown();
}

#[test]
fn peer_fetch_preferred_and_master_fallback_on_worker_loss() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = {
        let mut c = conf();
        // Short connect timeout so each dead-peer attempt fails fast.
        c.set("ignite.rpc.connect.timeout.ms", "300");
        c
    };
    let (sc, workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    let payload = Value::Str("broadcast-me ".repeat(80)); // ≫ 64 B → many blocks
    let total = mpignite::ser::to_bytes(&payload).len() as u64;
    let b = sc.broadcast(payload.clone()).unwrap();
    assert_eq!(b.total_bytes() as u64, total);

    // First worker assembles from the master (no peers exist yet) …
    let m0 = metric("broadcast.bytes.fetched.master");
    let p0 = metric("broadcast.bytes.fetched.peer");
    assert_eq!(*workers[0].engine().broadcast_value(b.id()).unwrap(), payload);
    assert_eq!(metric("broadcast.bytes.fetched.master") - m0, total);
    assert_eq!(metric("broadcast.bytes.fetched.peer") - p0, 0);

    // … and the second worker pulls every block from that peer.
    let m1 = metric("broadcast.bytes.fetched.master");
    let p1 = metric("broadcast.bytes.fetched.peer");
    assert_eq!(*workers[1].engine().broadcast_value(b.id()).unwrap(), payload);
    assert_eq!(metric("broadcast.bytes.fetched.peer") - p1, total, "peer copy preferred");
    assert_eq!(metric("broadcast.bytes.fetched.master") - m1, 0);

    // Kill the peer holding the only worker replica, drop the second
    // worker's copy, and re-fetch immediately (the dead worker is still
    // inside its heartbeat window, so the master still lists it): every
    // block's peer attempt fails and falls back to the master copy.
    workers[0].kill();
    workers[1].engine().clear_broadcast(b.id());
    let m2 = metric("broadcast.bytes.fetched.master");
    let f2 = metric("broadcast.fetch.peer.failures");
    assert_eq!(*workers[1].engine().broadcast_value(b.id()).unwrap(), payload);
    assert!(
        metric("broadcast.fetch.peer.failures") > f2,
        "the dead peer must have been tried first"
    );
    assert_eq!(
        metric("broadcast.bytes.fetched.master") - m2,
        total,
        "every block fell back to the master/driver copy"
    );

    // The cluster still completes plan jobs on the survivor once the
    // loss is detected.
    std::thread::sleep(Duration::from_millis(2500)); // > worker.timeout.ms
    let got = counts_of(
        sc.parallelize_values_with(source_rows(), 2)
            .map_named("bc.pair")
            .reduce_by_key(2, AggSpec::SumI64)
            .collect()
            .unwrap(),
    );
    assert_eq!(got.len(), 7, "job completes after worker loss");
    assert_eq!(master.broadcast_table_len(), 1, "user broadcast outlives the job GC");
    b.destroy();
    assert_eq!(master.broadcast_table_len(), 0);
    master.shutdown();
}

#[test]
fn failed_plan_job_leaks_no_broadcast_or_shuffle_state() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = conf();
    let (sc, workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    // The map stage fetches the broadcast source, then dies on an
    // unregistered op — after the failure, NEITHER plane may leak.
    let err = sc
        .parallelize_values_with(source_rows(), 4)
        .map_named("bc.this_op_does_not_exist")
        .reduce_by_key(2, AggSpec::SumI64)
        .collect()
        .unwrap_err();
    assert!(err.to_string().contains("this_op_does_not_exist"), "got: {err}");

    assert_eq!(master.shuffle_table_len(), 0, "failed job left shuffle table entries");
    assert_eq!(master.broadcast_table_len(), 0, "failed job left broadcast table entries");
    wait_workers_drained(&workers, "failed job");
    master.shutdown();
}
