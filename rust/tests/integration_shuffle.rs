//! Integration tests for the tiered shuffle pipeline: a cluster-mode
//! `reduce_by_key` whose reduce tasks pull buckets from a *different
//! worker* over the shuffle RPC endpoints, a local job with the memory
//! budget forced to zero so every bucket spills to the `DiskStore` and
//! is read back — both compared against the pure in-memory path — and
//! the PR 5 fast-path acceptance: a 2-worker 4-map × 4-reduce plan job
//! whose remote round-trips are batched (`shuffle.fetch_multi` ≤
//! workers × reduces, down from maps × reduces), whose tiny memory
//! budget forces LRU demotions, and whose compressed/batched/evicting
//! result is bit-identical to the plain path.

use mpignite::cluster::{Master, Worker};
use mpignite::config::IgniteConf;
use mpignite::rdd::{AggSpec, ParallelCollectionNode, RddNode, ShuffledNode};
use mpignite::ser::Value;
use mpignite::shuffle::HashPartitioner;
use mpignite::IgniteContext;
use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Serializes the cluster tests in this binary: they assert exact or
/// upper-bounded deltas of process-global shuffle metrics, which
/// interleaved cluster tests would skew.
static SERIAL: Mutex<()> = Mutex::new(());

fn metric(name: &str) -> u64 {
    mpignite::metrics::global().counter(name).get()
}

fn conf() -> IgniteConf {
    let mut c = IgniteConf::new();
    c.set("ignite.worker.heartbeat.ms", "50");
    c.set("ignite.worker.timeout.ms", "2000");
    c
}

/// The wordcount corpus used by the cluster test, pre-split into four map
/// partitions.
fn corpus() -> Vec<Vec<(String, u64)>> {
    let parts: [&[&str]; 4] = [
        &["apple", "pear", "apple", "plum"],
        &["pear", "pear", "kiwi"],
        &["apple", "plum", "plum", "kiwi", "apple"],
        &["kiwi", "apple", "fig"],
    ];
    parts
        .iter()
        .map(|words| words.iter().map(|w| (w.to_string(), 1u64)).collect())
        .collect()
}

fn oracle(parts: &[Vec<(String, u64)>]) -> HashMap<String, u64> {
    let mut out = HashMap::new();
    for part in parts {
        for (w, n) in part {
            *out.entry(w.clone()).or_insert(0) += n;
        }
    }
    out
}

/// Identical reduce_by_key lineage built against a given engine's data.
/// Ids are pinned so two workers agree on the shuffle identity, the way a
/// driver shipping one DAG to every worker would.
fn wordcount_node(shuffle_id: u64) -> ShuffledNode<String, u64> {
    ShuffledNode {
        id: shuffle_id + 1,
        shuffle_id,
        parent: Arc::new(ParallelCollectionNode {
            id: shuffle_id + 2,
            partitions: Arc::new(corpus()),
        }),
        partitioner: HashPartitioner::new(2),
        agg: Arc::new(|a, b| a + b),
    }
}

#[test]
fn cluster_reduce_fetches_buckets_from_remote_worker() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let c = conf();
    let master = Master::start(&c, 0).unwrap();
    let worker_a = Worker::start(&c, master.address()).unwrap();
    let worker_b = Worker::start(&c, master.address()).unwrap();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    // One shuffle id shared by both workers (a driver would ship it).
    let shuffle_id = 0xB00C_0001;
    let node_a = wordcount_node(shuffle_id);
    let node_b = wordcount_node(shuffle_id);

    // Extract the map stage from lineage on each worker and run a subset
    // of its tasks there: maps 0,1 on worker A; maps 2,3 on worker B.
    let mut stages_a = Vec::new();
    node_a.stage_deps(&mut stages_a, &mut HashSet::new());
    let mut stages_b = Vec::new();
    node_b.stage_deps(&mut stages_b, &mut HashSet::new());
    assert_eq!(stages_a.len(), 1);
    for map_idx in [0usize, 1] {
        (stages_a[0].run_task)(map_idx, worker_a.engine()).unwrap();
    }
    for map_idx in [2usize, 3] {
        (stages_b[0].run_task)(map_idx, worker_b.engine()).unwrap();
    }

    // Worker B only ran maps 2,3 locally; completion must resolve
    // through the master's map-output table.
    assert!(!worker_b.engine().shuffle.is_complete(shuffle_id));
    assert_eq!(worker_b.engine().shuffle.map_count(shuffle_id), Some(4));

    // Reduce both partitions on worker B: buckets of maps 0 and 1 are
    // only on worker A and must arrive via the shuffle.fetch endpoint.
    let fetches_before = mpignite::metrics::global().counter("shuffle.remote.fetches").get();
    let served_before =
        mpignite::metrics::global().counter("cluster.shuffle.fetches.served").get();
    let mut merged: HashMap<String, u64> = HashMap::new();
    for part in 0..2 {
        for (k, v) in node_b.compute(part, worker_b.engine()).unwrap() {
            assert!(merged.insert(k, v).is_none(), "keys are disjoint across partitions");
        }
    }
    let fetched =
        mpignite::metrics::global().counter("shuffle.remote.fetches").get() - fetches_before;
    let served =
        mpignite::metrics::global().counter("cluster.shuffle.fetches.served").get() - served_before;
    assert!(fetched >= 2, "maps 0,1 x 2 partitions should fetch remotely, got {fetched}");
    assert!(served >= 2, "worker A must have served the fetched buckets, got {served}");

    assert_eq!(merged, oracle(&corpus()), "distributed result matches the sequential oracle");

    // Cross-check against the pure in-memory single-process path.
    let sc = IgniteContext::local(4);
    let local = sc
        .parallelize_with(corpus().into_iter().flatten().collect(), 4)
        .reduce_by_key(2, |a, b| a + b)
        .collect_map()
        .unwrap();
    assert_eq!(merged, local, "remote-fetch result identical to in-memory path");

    master.shutdown();
}

/// 1200 pair rows over 300 distinct padded keys: enough byte volume that
/// a tiny worker budget forces LRU demotions, repetitive enough that LZ
/// compression wins, and every key summed across all 4 map partitions so
/// the aggregation is real.
fn plan_rows() -> Vec<Value> {
    (0..1200)
        .map(|i| {
            Value::List(vec![
                Value::Str(format!("key-{:03}-padding-padding", i % 300)),
                Value::I64(i as i64),
            ])
        })
        .collect()
}

/// Collected `List([Str, I64])` rows as a key → summed-value map.
fn to_map(rows: Vec<Value>) -> HashMap<String, i64> {
    let mut out = HashMap::new();
    for row in rows {
        match row {
            Value::List(kv) if kv.len() == 2 => match (&kv[0], &kv[1]) {
                (Value::Str(k), Value::I64(v)) => {
                    assert!(out.insert(k.clone(), *v).is_none(), "duplicate key {k}");
                }
                other => panic!("unexpected pair {other:?}"),
            },
            other => panic!("unexpected row {other:?}"),
        }
    }
    out
}

/// Run the 4-map × 4-reduce plan wordcount on a fresh 2-worker cluster
/// built from `c`, returning the result map and the
/// `shuffle.fetch.multi.calls` / `shuffle.fetch.batch.calls` deltas the
/// job produced (the per-task streaming endpoint and the cross-task
/// batch-prefetch endpoint — between them, every remote round-trip).
fn run_cluster_plan_job(c: &IgniteConf) -> (HashMap<String, i64>, u64, u64) {
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    let multi_before = metric("shuffle.fetch.multi.calls");
    let batch_before = metric("shuffle.fetch.batch.calls");
    let got = sc
        .parallelize_values_with(plan_rows(), 4)
        .reduce_by_key(4, AggSpec::SumI64)
        .collect()
        .unwrap();
    let multi = metric("shuffle.fetch.multi.calls") - multi_before;
    let batch = metric("shuffle.fetch.batch.calls") - batch_before;
    master.shutdown();
    (to_map(got), multi, batch)
}

#[test]
fn plan_job_batches_fetches_and_evicts_under_pressure_bit_identically() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());

    // Reference: the plain single-process path (no cluster, default
    // tiers) — what the compressed/batched/evicting run must reproduce.
    let local = IgniteContext::local(4);
    let want = to_map(
        local
            .parallelize_values_with(plan_rows(), 4)
            .reduce_by_key(4, AggSpec::SumI64)
            .collect()
            .unwrap(),
    );
    assert_eq!(want.len(), 300);

    let mut c = conf();
    c.set("ignite.shuffle.compress", "true");
    // Tiny budget — bigger than any single ~1-2 KiB bucket but far
    // smaller than a worker's 8-bucket share — so admission must demote
    // LRU residents instead of freezing the tier (a budget below the
    // single-bucket size would take the direct-spill path and never
    // evict).
    c.set("ignite.shuffle.memory.bytes", "3000");

    let fetches_before = metric("shuffle.remote.fetches");
    let evictions_before = metric("shuffle.evictions");
    let saved_before = metric("shuffle.bytes.saved");

    let (got, multi_calls, batch_calls) = run_cluster_plan_job(&c);
    assert_eq!(got, want, "compressed/batched/evicting result must be bit-identical");

    // Batched fetch: remote round-trips are streamed now (per-task
    // fetch_multi plus the cross-task batch prefetch), bounded by
    // workers × reduces + workers (2 × 4 + 2 = 10) instead of
    // maps × reduces (16).
    let fetched = metric("shuffle.remote.fetches") - fetches_before;
    assert!(fetched >= 1, "reduce tasks must fetch across workers");
    assert!(fetched <= 10, "remote round-trips must stay batched, got {fetched}");
    assert!(
        multi_calls + batch_calls >= 1,
        "a batched endpoint must carry the job ({multi_calls} multi, {batch_calls} batch)"
    );

    // LRU pressure: resident buckets were demoted, not just new writes
    // spilled; compression saved real bytes on the way.
    assert!(metric("shuffle.evictions") > evictions_before, "tiny budget must demote buckets");
    assert!(metric("shuffle.bytes.saved") > saved_before, "padded keys must compress");
}

#[test]
fn cluster_plan_job_ships_shuffle_bytes_zero_copy() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());

    // The assembled-frames CI lane (`MPIGNITE_RPC_VECTORED=false`) turns
    // scatter-gather framing off globally; there the zero-copy counters
    // legitimately stay flat. Results are still checked either way —
    // only the metric assertions are lane-gated.
    let vectored_off = std::env::var("MPIGNITE_RPC_VECTORED")
        .map(|v| matches!(v.trim().to_ascii_lowercase().as_str(), "false" | "0" | "no"))
        .unwrap_or(false);

    let local = IgniteContext::local(4);
    let want = to_map(
        local
            .parallelize_values_with(plan_rows(), 4)
            .reduce_by_key(4, AggSpec::SumI64)
            .collect()
            .unwrap(),
    );

    let zc_before = metric("rpc.bytes.zero_copy");
    let writes_before = metric("rpc.writes.vectored");
    let (got, _multi_calls, _batch_calls) = run_cluster_plan_job(&conf());
    assert_eq!(got, want, "vectored-framing result must match the in-memory path");

    if vectored_off {
        return;
    }
    let zc = metric("rpc.bytes.zero_copy") - zc_before;
    let writes = metric("rpc.writes.vectored") - writes_before;
    assert!(
        writes >= 1,
        "cluster frames must go out through the scatter-gather write path"
    );
    assert!(
        zc >= 1,
        "fetch_multi bucket bytes must ship buffer-to-wire without reassembly"
    );
}

#[test]
fn fetch_batch_frame_size_changes_round_trips_not_results() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());

    // batch.bytes=1: every streaming frame (fetch_multi or fetch_batch)
    // carries exactly one bucket (the server always includes at least
    // one), so the client re-asks once per remote bucket — the
    // per-bucket baseline. The default frame budget carries a whole
    // worker's share per round-trip.
    let mut tiny = conf();
    tiny.set("ignite.shuffle.fetch.batch.bytes", "1");
    let (got_tiny, multi_tiny, batch_tiny) = run_cluster_plan_job(&tiny);
    let calls_tiny = multi_tiny + batch_tiny;

    let batched = conf();
    let (got_batched, multi_batched, batch_batched) = run_cluster_plan_job(&batched);
    let calls_batched = multi_batched + batch_batched;

    assert_eq!(got_tiny, got_batched, "frame size must not change results");
    assert!(calls_tiny >= 1 && calls_batched >= 1);
    assert!(
        calls_tiny > calls_batched,
        "one-bucket frames must cost more round-trips ({calls_tiny} vs {calls_batched})"
    );
}

#[test]
fn task_batch_prefetch_collapses_round_trips_per_peer() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());

    let local = IgniteContext::local(4);
    let want = to_map(
        local
            .parallelize_values_with(plan_rows(), 4)
            .reduce_by_key(4, AggSpec::SumI64)
            .collect()
            .unwrap(),
    );

    // Default budgets: each worker's task batch prefetches ALL of its
    // reduce tasks' remote buckets through `shuffle.fetch_batch` — one
    // combined stream per remote peer, not one per (task, peer). With 2
    // workers and the whole corpus a fraction of the frame budget, that
    // is at most one stream each way plus slack, strictly below the 4
    // per-task `fetch_multi` round-trips the task-by-task path needs
    // (4 reduce tasks × 1 remote peer).
    let (got, multi_calls, batch_calls) = run_cluster_plan_job(&conf());
    assert_eq!(got, want, "prefetched result must be bit-identical");
    assert!(batch_calls >= 1, "the cross-task batch stream must carry the prefetch");
    assert!(
        multi_calls + batch_calls < 4,
        "whole-batch streams must undercut per-task round-trips \
         ({multi_calls} multi + {batch_calls} batch)"
    );
}

#[test]
fn zero_budget_job_spills_every_bucket_and_matches_in_memory() {
    let pairs: Vec<(i64, i64)> = (0..500).map(|x| (x % 13, x)).collect();

    // Reference: effectively-unbounded budget, nothing spills.
    let mut mem_conf = IgniteConf::new();
    mem_conf.set("ignite.shuffle.memory.bytes", usize::MAX.to_string());
    let sc_mem = IgniteContext::with_conf(mem_conf).unwrap();
    let want = sc_mem
        .parallelize_with(pairs.clone(), 8)
        .reduce_by_key(4, |a, b| a + b)
        .collect_map()
        .unwrap();
    assert_eq!(sc_mem.engine().shuffle.spilled_count(), 0, "unbounded budget never spills");

    // Forced spill: budget 0 pushes every bucket through the DiskStore.
    let mut spill_conf = IgniteConf::new();
    spill_conf.set("ignite.shuffle.memory.bytes", "0");
    let sc_spill = IgniteContext::with_conf(spill_conf).unwrap();
    let got = sc_spill
        .parallelize_with(pairs, 8)
        .reduce_by_key(4, |a, b| a + b)
        .collect_map()
        .unwrap();
    assert!(
        sc_spill.engine().shuffle.spilled_count() > 0,
        "budget 0 must spill buckets to disk"
    );
    assert_eq!(sc_spill.engine().shuffle.mem_used(), 0, "no bucket bytes resident in memory");

    assert_eq!(got, want, "all-spilled result identical to in-memory path");
}

#[test]
fn spilled_shuffle_survives_map_output_loss_via_lineage() {
    // Lose one map task's (spilled) output mid-lineage; the scheduler's
    // recompute path must re-register the spilled blocks transparently.
    let mut c = IgniteConf::new();
    c.set("ignite.shuffle.memory.bytes", "0");
    let sc = IgniteContext::with_conf(c).unwrap();
    let rdd = sc
        .parallelize_with((0..200i64).collect(), 4)
        .map(|x| (x % 10, x))
        .reduce_by_key(4, |a, b| a + b);
    let before = rdd.collect_map().unwrap();
    for shuffle_id in 0..10_000u64 {
        sc.engine().shuffle.lose_map_output(shuffle_id, 0);
    }
    let after = rdd.collect_map().unwrap();
    assert_eq!(before, after, "recomputed spilled shuffle matches");
}
