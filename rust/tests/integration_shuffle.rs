//! Integration tests for the tiered shuffle pipeline (PR 1): a
//! cluster-mode `reduce_by_key` whose reduce tasks pull buckets from a
//! *different worker* over the `shuffle.fetch` RPC endpoint, and a local
//! job with the memory budget forced to zero so every bucket spills to
//! the `DiskStore` and is read back — both compared against the pure
//! in-memory path.

use mpignite::cluster::{Master, Worker};
use mpignite::config::IgniteConf;
use mpignite::rdd::{ParallelCollectionNode, RddNode, ShuffledNode};
use mpignite::shuffle::HashPartitioner;
use mpignite::IgniteContext;
use std::collections::{HashMap, HashSet};
use std::sync::Arc;
use std::time::Duration;

fn conf() -> IgniteConf {
    let mut c = IgniteConf::new();
    c.set("ignite.worker.heartbeat.ms", "50");
    c.set("ignite.worker.timeout.ms", "2000");
    c
}

/// The wordcount corpus used by the cluster test, pre-split into four map
/// partitions.
fn corpus() -> Vec<Vec<(String, u64)>> {
    let parts: [&[&str]; 4] = [
        &["apple", "pear", "apple", "plum"],
        &["pear", "pear", "kiwi"],
        &["apple", "plum", "plum", "kiwi", "apple"],
        &["kiwi", "apple", "fig"],
    ];
    parts
        .iter()
        .map(|words| words.iter().map(|w| (w.to_string(), 1u64)).collect())
        .collect()
}

fn oracle(parts: &[Vec<(String, u64)>]) -> HashMap<String, u64> {
    let mut out = HashMap::new();
    for part in parts {
        for (w, n) in part {
            *out.entry(w.clone()).or_insert(0) += n;
        }
    }
    out
}

/// Identical reduce_by_key lineage built against a given engine's data.
/// Ids are pinned so two workers agree on the shuffle identity, the way a
/// driver shipping one DAG to every worker would.
fn wordcount_node(shuffle_id: u64) -> ShuffledNode<String, u64> {
    ShuffledNode {
        id: shuffle_id + 1,
        shuffle_id,
        parent: Arc::new(ParallelCollectionNode {
            id: shuffle_id + 2,
            partitions: Arc::new(corpus()),
        }),
        partitioner: HashPartitioner::new(2),
        agg: Arc::new(|a, b| a + b),
    }
}

#[test]
fn cluster_reduce_fetches_buckets_from_remote_worker() {
    let c = conf();
    let master = Master::start(&c, 0).unwrap();
    let worker_a = Worker::start(&c, master.address()).unwrap();
    let worker_b = Worker::start(&c, master.address()).unwrap();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    // One shuffle id shared by both workers (a driver would ship it).
    let shuffle_id = 0xB00C_0001;
    let node_a = wordcount_node(shuffle_id);
    let node_b = wordcount_node(shuffle_id);

    // Extract the map stage from lineage on each worker and run a subset
    // of its tasks there: maps 0,1 on worker A; maps 2,3 on worker B.
    let mut stages_a = Vec::new();
    node_a.stage_deps(&mut stages_a, &mut HashSet::new());
    let mut stages_b = Vec::new();
    node_b.stage_deps(&mut stages_b, &mut HashSet::new());
    assert_eq!(stages_a.len(), 1);
    for map_idx in [0usize, 1] {
        (stages_a[0].run_task)(map_idx, worker_a.engine()).unwrap();
    }
    for map_idx in [2usize, 3] {
        (stages_b[0].run_task)(map_idx, worker_b.engine()).unwrap();
    }

    // Worker B only ran maps 2,3 locally; completion must resolve
    // through the master's map-output table.
    assert!(!worker_b.engine().shuffle.is_complete(shuffle_id));
    assert_eq!(worker_b.engine().shuffle.map_count(shuffle_id), Some(4));

    // Reduce both partitions on worker B: buckets of maps 0 and 1 are
    // only on worker A and must arrive via the shuffle.fetch endpoint.
    let fetches_before = mpignite::metrics::global().counter("shuffle.remote.fetches").get();
    let served_before =
        mpignite::metrics::global().counter("cluster.shuffle.fetches.served").get();
    let mut merged: HashMap<String, u64> = HashMap::new();
    for part in 0..2 {
        for (k, v) in node_b.compute(part, worker_b.engine()).unwrap() {
            assert!(merged.insert(k, v).is_none(), "keys are disjoint across partitions");
        }
    }
    let fetched =
        mpignite::metrics::global().counter("shuffle.remote.fetches").get() - fetches_before;
    let served =
        mpignite::metrics::global().counter("cluster.shuffle.fetches.served").get() - served_before;
    assert!(fetched >= 2, "maps 0,1 x 2 partitions should fetch remotely, got {fetched}");
    assert!(served >= 2, "worker A must have served the fetched buckets, got {served}");

    assert_eq!(merged, oracle(&corpus()), "distributed result matches the sequential oracle");

    // Cross-check against the pure in-memory single-process path.
    let sc = IgniteContext::local(4);
    let local = sc
        .parallelize_with(corpus().into_iter().flatten().collect(), 4)
        .reduce_by_key(2, |a, b| a + b)
        .collect_map()
        .unwrap();
    assert_eq!(merged, local, "remote-fetch result identical to in-memory path");

    master.shutdown();
}

#[test]
fn zero_budget_job_spills_every_bucket_and_matches_in_memory() {
    let pairs: Vec<(i64, i64)> = (0..500).map(|x| (x % 13, x)).collect();

    // Reference: effectively-unbounded budget, nothing spills.
    let mut mem_conf = IgniteConf::new();
    mem_conf.set("ignite.shuffle.memory.bytes", usize::MAX.to_string());
    let sc_mem = IgniteContext::with_conf(mem_conf).unwrap();
    let want = sc_mem
        .parallelize_with(pairs.clone(), 8)
        .reduce_by_key(4, |a, b| a + b)
        .collect_map()
        .unwrap();
    assert_eq!(sc_mem.engine().shuffle.spilled_count(), 0, "unbounded budget never spills");

    // Forced spill: budget 0 pushes every bucket through the DiskStore.
    let mut spill_conf = IgniteConf::new();
    spill_conf.set("ignite.shuffle.memory.bytes", "0");
    let sc_spill = IgniteContext::with_conf(spill_conf).unwrap();
    let got = sc_spill
        .parallelize_with(pairs, 8)
        .reduce_by_key(4, |a, b| a + b)
        .collect_map()
        .unwrap();
    assert!(
        sc_spill.engine().shuffle.spilled_count() > 0,
        "budget 0 must spill buckets to disk"
    );
    assert_eq!(sc_spill.engine().shuffle.mem_used(), 0, "no bucket bytes resident in memory");

    assert_eq!(got, want, "all-spilled result identical to in-memory path");
}

#[test]
fn spilled_shuffle_survives_map_output_loss_via_lineage() {
    // Lose one map task's (spilled) output mid-lineage; the scheduler's
    // recompute path must re-register the spilled blocks transparently.
    let mut c = IgniteConf::new();
    c.set("ignite.shuffle.memory.bytes", "0");
    let sc = IgniteContext::with_conf(c).unwrap();
    let rdd = sc
        .parallelize_with((0..200i64).collect(), 4)
        .map(|x| (x % 10, x))
        .reduce_by_key(4, |a, b| a + b);
    let before = rdd.collect_map().unwrap();
    for shuffle_id in 0..10_000u64 {
        sc.engine().shuffle.lose_map_output(shuffle_id, 0);
    }
    let after = rdd.collect_map().unwrap();
    assert_eq!(before, after, "recomputed spilled shuffle matches");
}
