//! Runtime integration: execute the real AOT artifacts via PJRT and check
//! numerics against pure-Rust references. Skips (with a notice) when
//! `make artifacts` has not run — CI runs it first.

use mpignite::rng::Xoshiro256;
use mpignite::runtime::{shared_service, TensorF32, XlaServiceHandle};
use std::sync::Arc;

fn svc() -> Option<Arc<XlaServiceHandle>> {
    match shared_service("artifacts") {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping runtime_exec tests: {e}");
            None
        }
    }
}

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seeded(seed);
    (0..n).map(|_| rng.next_f32() - 0.5).collect()
}

fn naive_matvec(a: &[f32], x: &[f32], m: usize, k: usize) -> Vec<f32> {
    (0..m)
        .map(|i| (0..k).map(|j| a[i * k + j] * x[j]).sum())
        .collect()
}

#[test]
fn manifest_lists_required_artifacts() {
    let Some(svc) = svc() else { return };
    for name in [
        "matvec_f32_64x64",
        "matvec_f32_256x256",
        "matvec_f32_1024x1024",
        "matvec_f32_256x1024",
        "matvec_f32_128x1024",
        "dot_f32_1024",
        "power_step_f32_1024",
    ] {
        assert!(svc.has(name), "missing artifact {name}");
    }
}

#[test]
fn matvec_artifact_matches_naive_reference() {
    let Some(svc) = svc() else { return };
    for n in [64usize, 256] {
        let a = rand_vec(n * n, 1);
        let x = rand_vec(n, 2);
        let y = svc
            .matvec(
                &format!("matvec_f32_{n}x{n}"),
                TensorF32::matrix(a.clone(), n, n),
                TensorF32::vec(x.clone()),
            )
            .unwrap();
        let want = naive_matvec(&a, &x, n, n);
        for i in 0..n {
            assert!(
                (y[i] - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()),
                "n={n} i={i}: {} vs {}",
                y[i],
                want[i]
            );
        }
    }
}

#[test]
fn rectangular_tile_artifact() {
    let Some(svc) = svc() else { return };
    let (m, k) = (128usize, 1024usize);
    let a = rand_vec(m * k, 3);
    let x = rand_vec(k, 4);
    let y = svc
        .matvec("matvec_f32_128x1024", TensorF32::matrix(a.clone(), m, k), TensorF32::vec(x.clone()))
        .unwrap();
    let want = naive_matvec(&a, &x, m, k);
    for i in 0..m {
        assert!((y[i] - want[i]).abs() < 2e-3 * (1.0 + want[i].abs()), "i={i}");
    }
}

#[test]
fn dot_artifact() {
    let Some(svc) = svc() else { return };
    let x = rand_vec(1024, 5);
    let y = rand_vec(1024, 6);
    let out = svc
        .exec("dot_f32_1024", vec![TensorF32::vec(x.clone()), TensorF32::vec(y.clone())])
        .unwrap();
    let want: f32 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
    assert!(out[0].dims.is_empty(), "dot returns a scalar");
    assert!((out[0].data[0] - want).abs() < 1e-2 * (1.0 + want.abs()));
}

#[test]
fn power_step_artifact_two_outputs() {
    let Some(svc) = svc() else { return };
    let n = 1024usize;
    // Symmetric-ish matrix via the apps generator.
    let a = mpignite::apps::gen_row_block(n, 0, n, 7);
    let x = vec![1.0f32 / (n as f32).sqrt(); n];
    let out = svc
        .exec(
            "power_step_f32_1024",
            vec![TensorF32::matrix(a, n, n), TensorF32::vec(x)],
        )
        .unwrap();
    assert_eq!(out.len(), 2, "x_next and eigenvalue estimate");
    assert_eq!(out[0].dims, vec![n]);
    // x_next has unit norm.
    let norm: f32 = out[0].data.iter().map(|v| v * v).sum::<f32>().sqrt();
    assert!((norm - 1.0).abs() < 1e-3, "norm {norm}");
    // Rayleigh estimate in a plausible band around the planted eig.
    let eig = out[1].data[0];
    assert!(eig > 1.0 && eig < 10.0, "eig {eig}");
}

#[test]
fn shape_validation_rejects_wrong_inputs() {
    let Some(svc) = svc() else { return };
    let err = svc
        .exec("matvec_f32_64x64", vec![TensorF32::vec(vec![0.0; 64])])
        .unwrap_err();
    assert!(err.to_string().contains("expected 2 inputs"));
    let err = svc
        .exec(
            "matvec_f32_64x64",
            vec![TensorF32::matrix(vec![0.0; 32 * 64], 32, 64), TensorF32::vec(vec![0.0; 64])],
        )
        .unwrap_err();
    assert!(err.to_string().contains("shape"), "got: {err}");
    assert!(svc.exec("no_such_artifact", vec![]).is_err());
}

#[test]
fn concurrent_execution_from_many_threads() {
    let Some(svc) = svc() else { return };
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let a = rand_vec(64 * 64, 10 + t);
            let x = rand_vec(64, 20 + t);
            let y = svc
                .matvec("matvec_f32_64x64", TensorF32::matrix(a.clone(), 64, 64), TensorF32::vec(x.clone()))
                .unwrap();
            let want = naive_matvec(&a, &x, 64, 64);
            for i in 0..64 {
                assert!((y[i] - want[i]).abs() < 1e-3 * (1.0 + want[i].abs()));
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}
