//! Asynchronous checkpoint-restart for peer gangs and driver-session
//! recovery, end to end on a real (in-process) cluster:
//!
//! * an 8-iteration k-means gang snapshotting every iteration is killed
//!   by a scripted `ckpt.save` fault at iteration 6 — the restarted gang
//!   restores the last *complete* epoch (5; epoch 6 is partial, one rank
//!   never registered it, and a partial epoch must never be served),
//!   replays only the tail (`peer.iterations.replayed` < kill point),
//!   and converges bit-identically to the fault-free closure reference;
//!   the master's checkpoint table is empty again at job end;
//! * a driver "crash" (the context is dropped mid-job) recovers through
//!   the session journal: `Master::reattach_session` finds the orphaned
//!   session's job, and `wait_job` hands back the result the crashed
//!   driver never saw — an unknown session id errors instead;
//! * with checkpointing off (interval 0) a scripted rank fault keeps the
//!   old restart-from-scratch semantics with ZERO checkpoint overhead:
//!   nothing saved, nothing restored, no bytes written, and the full
//!   iteration count replayed.

use mpignite::apps;
use mpignite::ckpt::sites;
use mpignite::cluster::Worker;
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use mpignite::rdd::PlanStageKind;
use std::sync::{Arc, Mutex, Once};
use std::time::{Duration, Instant};

/// Serializes the tests in this binary: they assert exact deltas of
/// process-global checkpoint metrics, which interleaved tests would skew.
static SERIAL: Mutex<()> = Mutex::new(());

static OPS: Once = Once::new();

const K: usize = 3;
const ITERS: usize = 8;
/// Iteration whose `ckpt.save` the scripted fault kills (rank 0, gen 0).
const KILL_AT: u64 = 6;

fn register_ops() {
    OPS.call_once(|| {
        apps::register_kmeans_peer("ckpt.test.kmeans", K, ITERS);
        // Identical math, but slow enough that the driver can "crash"
        // while the job is still running (sleeps don't change results).
        register_peer_op("ckpt.test.kmeans_slow", |comm, rows| {
            let points = apps::peer_points(&rows)?;
            let mut centroids = apps::kmeans_init(comm, &points, K)?;
            for _ in 0..ITERS {
                std::thread::sleep(Duration::from_millis(60));
                centroids = apps::kmeans_iteration(comm, &points, &centroids)?;
            }
            Ok(centroids.into_iter().map(Value::F64Vec).collect())
        });
    });
}

fn metric(name: &str) -> u64 {
    mpignite::metrics::global().counter(name).get()
}

/// The CI chaos soak reruns this binary under seeded ambient faults,
/// which add gang restarts beyond the scripted ones — exact-delta metric
/// assertions only hold in the deterministic (unseeded) runs.
fn chaos() -> bool {
    std::env::var("MPIGNITE_FAULT_INJECT_SEED").is_ok()
}

fn conf() -> IgniteConf {
    let mut c = IgniteConf::new();
    c.set("ignite.worker.heartbeat.ms", "50");
    c.set("ignite.worker.timeout.ms", "600");
    // A gang whose sibling died must unblock its collectives well before
    // the peer-section deadline.
    c.set("ignite.comm.recv.timeout.ms", "3000");
    c.set("ignite.checkpoint.interval.iters", "1");
    c
}

/// 24 2-D points around three well-separated centers (the
/// integration_peer fixture), so k-means with k=3 is stable.
fn points() -> Vec<Value> {
    (0..24)
        .map(|i| {
            let center = match i % 3 {
                0 => (0.0, 0.0),
                1 => (10.0, 0.0),
                _ => (0.0, 10.0),
            };
            let jitter = 0.05 * i as f64;
            Value::F64Vec(vec![center.0 + jitter, center.1 - jitter])
        })
        .collect()
}

fn setup(c: &IgniteConf, n: usize) -> (IgniteContext, Vec<Arc<Worker>>) {
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..n).map(|_| Worker::start(c, master.address()).unwrap()).collect();
    master.wait_for_workers(n, Duration::from_secs(5)).unwrap();
    (sc, workers)
}

/// The single-process closure path over the same points — the fault-free
/// reference every restored run must reproduce bit-for-bit. (Each
/// iteration's centroids are identical on every rank, so restoring any
/// complete epoch rejoins exactly this trajectory.)
fn closure_reference() -> Vec<Value> {
    let sc = IgniteContext::local(2);
    sc.parallelize_with(points(), 2)
        .map_partitions_peer(|comm, rows| apps::kmeans_peer_step(comm, rows, K, ITERS))
        .unwrap()
        .collect()
        .unwrap()
}

fn wait_workers_drained(workers: &[Arc<Worker>]) {
    let deadline = Instant::now() + Duration::from_secs(3);
    loop {
        let buckets: usize = workers.iter().map(|w| w.engine().shuffle.bucket_count()).sum();
        if buckets == 0 {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "job.clear never drained the workers' peer buckets ({buckets} left)"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

#[test]
fn gang_killed_mid_iteration_restores_last_complete_epoch_bit_identically() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = conf();
    let (sc, workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    let job = sc.peer_rdd(points(), 2, "ckpt.test.kmeans");
    let peer_id = job
        .plan()
        .stages()
        .iter()
        .find(|s| s.kind == PlanStageKind::Peer)
        .expect("plan has a peer stage")
        .id;
    // Kill rank 0 inside iteration KILL_AT's `ckpt.save` (round-robin
    // places rank 0 on the first-registered worker). Rank 1 finishes
    // iteration KILL_AT and registers its snapshot before blocking on
    // the dead sibling — so epoch KILL_AT exists but is PARTIAL (one of
    // two ranks), while epochs 0..KILL_AT-1 are complete. The restart
    // must restore KILL_AT-1, never the partial epoch.
    workers[0].engine().fault.fail_site(sites::SAVE, peer_id, 0, KILL_AT);

    let restarts_before = metric("peer.gang.restarts");
    let saved_before = metric("ckpt.epochs.saved");
    let bytes_before = metric("ckpt.bytes.written");
    let restored_before = metric("ckpt.epochs.restored");
    let replayed_before = metric("peer.iterations.replayed");

    let got = job.collect().unwrap();

    // Both ranks snapshotted asynchronously and the restart restored.
    assert!(
        metric("ckpt.epochs.saved") - saved_before >= ITERS as u64,
        "background writers must have registered per-rank epochs"
    );
    assert!(metric("ckpt.bytes.written") - bytes_before > 0);
    assert!(
        metric("ckpt.epochs.restored") - restored_before >= 1,
        "the restarted gang must restore from a complete epoch"
    );

    let replayed = metric("peer.iterations.replayed") - replayed_before;
    if !chaos() {
        assert_eq!(
            metric("peer.gang.restarts") - restarts_before,
            1,
            "exactly one gang restart (fresh communicator generation)"
        );
        // Restore at epoch KILL_AT-1 resumes at iteration KILL_AT: only
        // the tail reruns — O(iters-since-checkpoint), not O(KILL_AT).
        // (The master relaunches as soon as ONE rank errors, so the
        // blocked sibling's last queued register may still be in flight;
        // the restored epoch is then slightly older — the lower bound
        // stays, the upper bound is what checkpointing buys.)
        assert!(
            replayed >= ITERS as u64 - KILL_AT,
            "replay must start past the restored epoch, got {replayed}"
        );
        assert!(
            replayed < KILL_AT,
            "replay O(tail) must beat restart-from-scratch O(kill point), got {replayed}"
        );
    } else {
        assert!(replayed >= 1, "a restarted gang replays at least its final iteration");
    }

    // Bit-identical to the fault-free trajectory.
    assert_eq!(got.len(), 2 * K);
    assert_eq!(got[..K], got[K..], "gang members must agree on the centroids");
    assert_eq!(got, closure_reference(), "restored run diverged from fault-free reference");

    // Job-end GC: every epoch — complete, partial and stale — is gone.
    assert_eq!(master.checkpoint_table_len(), 0, "job.clear must empty the checkpoint table");
    assert_eq!(master.shuffle_table_len(), 0);
    wait_workers_drained(&workers);
    master.shutdown();
}

#[test]
fn crashed_driver_reattaches_session_and_recovers_job_result() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = conf();
    let (sc, _workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    let reattached_before = metric("jobserver.sessions.reattached");

    // Submit through the job server, then "crash" the driver: the
    // context (and the plan handle) drop, but the master — the cluster's
    // brain — keeps running the journaled job.
    let session = master.new_session();
    let job = sc.peer_rdd(points(), 2, "ckpt.test.kmeans_slow");
    let job_id = master.submit_job(session, job.plan()).unwrap();
    drop(job);
    drop(sc);

    // A recovering driver knows only its session id. Reattaching finds
    // the journaled job (very likely still running — the slow op sleeps
    // 60ms per iteration) and refreshes the session's activity clock.
    std::thread::sleep(Duration::from_millis(150));
    let jobs = master.reattach_session(session).unwrap();
    assert_eq!(jobs.len(), 1, "the session journal holds exactly the submitted job");
    assert_eq!(jobs[0].0, job_id);
    assert_eq!(
        metric("jobserver.sessions.reattached") - reattached_before,
        1,
        "reattach must be counted"
    );

    // The reattached driver collects the result it never saw.
    let got = master.wait_job(job_id, Duration::from_secs(15)).unwrap();
    assert_eq!(got, closure_reference(), "recovered result diverged");

    // A session id the master never issued (or already GC'd) errors.
    let err = master.reattach_session(u64::MAX).unwrap_err();
    assert!(err.to_string().contains("session"), "got: {err}");
    master.shutdown();
}

#[test]
fn checkpoint_off_keeps_restart_from_scratch_with_zero_overhead() {
    let _serial = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    register_ops();
    let c = {
        let mut c = conf();
        // Explicit off — overrides the matrix lane's MPIGNITE_* env too.
        c.set("ignite.checkpoint.interval.iters", "0");
        c
    };
    let (sc, workers) = setup(&c, 2);
    let master = sc.master().unwrap().clone();

    let job = sc.peer_rdd(points(), 2, "ckpt.test.kmeans");
    let peer_id = job
        .plan()
        .stages()
        .iter()
        .find(|s| s.kind == PlanStageKind::Peer)
        .expect("plan has a peer stage")
        .id;
    workers[0].engine().fault.fail_task(peer_id, 0, 0);

    let saved_before = metric("ckpt.epochs.saved");
    let bytes_before = metric("ckpt.bytes.written");
    let restored_before = metric("ckpt.epochs.restored");
    let replayed_before = metric("peer.iterations.replayed");

    let got = job.collect().unwrap();

    // Old semantics exactly: the restarted gang reruns from iteration 0
    // (the whole O(iters) replay checkpointing exists to avoid) ...
    assert_eq!(
        metric("peer.iterations.replayed") - replayed_before,
        ITERS as u64,
        "checkpoint-off restart must replay from scratch"
    );
    // ... and the disabled handle touches nothing: no snapshot encoded,
    // no writer spawned, no register RPC, no restore probe.
    assert_eq!(metric("ckpt.epochs.saved") - saved_before, 0);
    assert_eq!(metric("ckpt.bytes.written") - bytes_before, 0);
    assert_eq!(metric("ckpt.epochs.restored") - restored_before, 0);
    assert_eq!(master.checkpoint_table_len(), 0);

    assert_eq!(got, closure_reference(), "post-restart result diverged");
    master.shutdown();
}
