//! Observability integration: with tracing on, a 2-worker plan job
//! yields a complete span tree (job → stage → task, fetch spans nested
//! under their tasks, 100% task coverage against the executed counter);
//! the master's cluster-wide metrics merge is bit-exactly the fold of
//! the per-worker snapshots it pulled; a streaming query records one
//! batch span per micro-batch with its plan job nested underneath; a
//! worker killed mid-job leaves `event.reissue` records in the job
//! profile; and with tracing off the task hot path allocates no span
//! records at all.

use mpignite::closure::register_op;
use mpignite::cluster::Worker;
use mpignite::config::IgniteConf;
use mpignite::metrics::RegistrySnapshot;
use mpignite::prelude::*;
use mpignite::trace;
use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

/// Heartbeat-timing-sensitive clusters; serialized like the other
/// cluster suites so concurrent test threads don't turn timing
/// assumptions into flakes (and so the process-global tracer ring is
/// only ever fed by one scenario at a time).
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

/// Tracing is set EXPLICITLY both ways: the CI traced matrix lane
/// exports `MPIGNITE_TRACE_ENABLED=true` (applied at `IgniteConf::new`),
/// and explicit sets win over the env overlay — so the off-path
/// scenario stays off even in that lane.
fn conf(traced: bool) -> IgniteConf {
    let mut c = IgniteConf::new();
    c.set("ignite.worker.heartbeat.ms", "50");
    c.set("ignite.worker.timeout.ms", "2000");
    c.set("ignite.worker.slots", "2");
    c.set("ignite.trace.enabled", if traced { "true" } else { "false" });
    c.set("ignite.trace.sample.rate", "1.0");
    c
}

fn register_ops() {
    // Str line -> List of List([Str(word), I64(1)]) pairs.
    register_op("obs.word_pairs", |v| match v {
        Value::Str(s) => Ok(Value::List(
            s.split_whitespace()
                .map(|w| Value::List(vec![Value::Str(w.to_string()), Value::I64(1)]))
                .collect(),
        )),
        other => Err(IgniteError::Invalid(format!(
            "word_pairs wants str, got {}",
            other.type_name()
        ))),
    });
    // Slow enough that a mid-job worker kill strands in-flight tasks.
    register_op("obs.nap400_inc", |v| match v {
        Value::I64(n) => {
            std::thread::sleep(Duration::from_millis(400));
            Ok(Value::I64(n + 1))
        }
        other => Err(IgniteError::Invalid(format!("nap wants i64, got {}", other.type_name()))),
    });
}

fn counter(name: &str) -> u64 {
    mpignite::metrics::global().counter(name).get()
}

fn values(range: std::ops::Range<i64>) -> Vec<Value> {
    range.map(Value::I64).collect()
}

/// `n` (word, 1) pairs over `distinct` distinct words.
fn wordcount_rows(n: usize, distinct: usize) -> Vec<Value> {
    (0..n)
        .map(|i| Value::List(vec![Value::Str(format!("word{}", i % distinct)), Value::I64(1)]))
        .collect()
}

#[test]
fn traced_plan_job_produces_complete_span_tree() {
    let _serial = lock();
    let mut c = conf(true);
    let export_dir = std::env::temp_dir().join(format!("mpignite-obs-{}", std::process::id()));
    c.set("ignite.trace.dir", export_dir.to_str().unwrap());
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    trace::global().clear();
    let executed0 = counter("cluster.tasks.executed");

    let counts = sc
        .parallelize_values_with(wordcount_rows(1200, 300), 4)
        .reduce_by_key(4, AggSpec::SumI64)
        .collect()
        .unwrap();
    assert_eq!(counts.len(), 300, "word count must stay correct with tracing on");
    let executed = counter("cluster.tasks.executed") - executed0;
    assert!(executed >= 8, "4 map + 4 reduce tasks executed");

    let jobs = master.traced_jobs();
    assert_eq!(jobs.len(), 1, "exactly one traced job");
    let profile = master.job_profile(jobs[0]).unwrap();

    // Root: the driver's job span.
    let root = profile.root().expect("job root span");
    assert_eq!(root.kind, "job");
    assert_eq!(root.parent_id, 0);

    // Stages: the reduce_by_key map stage and the result stage, both
    // directly under the job root.
    let stages = profile.spans_of_kind("stage");
    assert_eq!(stages.len(), 2, "shuffle stage + result stage");
    for s in &stages {
        assert_eq!(s.parent_id, root.span_id, "stage spans parent under the job root");
    }
    assert!(stages.iter().any(|s| s.label("kind") == Some("shuffle")));
    assert!(stages.iter().any(|s| s.label("kind") == Some("result")));
    let stage_ids: HashSet<u64> = stages.iter().map(|s| s.span_id).collect();

    // 100% task coverage: every executed task recorded exactly one span,
    // each nested under its stage.
    let tasks = profile.spans_of_kind("task");
    assert_eq!(tasks.len() as u64, executed, "one span per executed task");
    for t in &tasks {
        assert!(stage_ids.contains(&t.parent_id), "task spans parent under a stage span");
        assert!(t.ok, "no task failed");
        assert!(t.label("task").is_some());
    }
    let task_ids: HashSet<u64> = tasks.iter().map(|s| s.span_id).collect();

    // Remote shuffle reads: fetch spans nest under the reading task, or
    // under the stage span for the batch prefetch issued on the whole
    // assignment's behalf before any task runs.
    let fetches = profile.spans_of_kind("fetch");
    assert!(!fetches.is_empty(), "a 2-worker shuffle must fetch remotely");
    for f in &fetches {
        assert!(
            task_ids.contains(&f.parent_id) || stage_ids.contains(&f.parent_id),
            "fetch spans parent under their task or stage"
        );
    }

    // Renderer, counter deltas, and the JSONL export on disk.
    let text = profile.render();
    assert!(text.contains("job profile — job"));
    assert!(text.contains("critical path: job"));
    assert!(
        profile.counter_deltas.iter().any(|(k, v)| k == "cluster.tasks.executed" && *v > 0),
        "job-scoped counter deltas recorded"
    );
    let exported =
        std::fs::read_to_string(export_dir.join(format!("job-{}.jsonl", jobs[0]))).unwrap();
    assert_eq!(
        exported.lines().count(),
        profile.spans.len() + 1,
        "JSONL export: one line per span plus the counters line"
    );
    let _ = std::fs::remove_dir_all(&export_dir);
    master.shutdown();
}

#[test]
fn cluster_metrics_merge_is_bit_exact_fold_of_worker_pulls() {
    let _serial = lock();
    let c = conf(false);
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    let counts = sc
        .parallelize_values_with(wordcount_rows(800, 200), 4)
        .reduce_by_key(2, AggSpec::SumI64)
        .collect()
        .unwrap();
    assert_eq!(counts.len(), 200);

    let (merged, parts) = master.cluster_metrics_detailed();
    assert_eq!(parts.len(), 2, "one snapshot per live worker");
    // The merged view must be EXACTLY the fold of the per-worker
    // snapshots it was built from: counters and gauges sum by name,
    // histograms merge bucket-by-bucket — bit-exact, no loss.
    let mut expected = RegistrySnapshot::default();
    for (_, snap) in &parts {
        expected.merge(snap);
    }
    assert_eq!(merged, expected, "merge must equal the fold of its parts");
    assert!(merged.counter("cluster.tasks.executed") > 0, "pulled counters are non-trivial");
    assert!(
        merged.histograms.iter().any(|(_, h)| h.count > 0),
        "latency histograms carry across the merge"
    );
    master.shutdown();
}

#[test]
fn streaming_batches_each_record_a_batch_span() {
    let _serial = lock();
    register_ops();
    let c = conf(true);
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    trace::global().clear();
    const BATCHES: u64 = 5;
    let source = MemoryStreamSource::new();
    for t in 0..BATCHES {
        source.push(vec![vec![Value::Str(format!("alpha beta b{t}"))]], t);
    }
    source.close();
    let spec = QuerySpec::reduce(
        "obs-wc",
        vec![OpSpec::FlatMapNamed { name: "obs.word_pairs".into() }],
        AggSpec::SumI64,
        2,
    );
    let mut query = sc.streaming().query(Box::new(source), spec).unwrap();
    query.run(Duration::from_secs(60)).unwrap();
    assert_eq!(query.batches_completed(), BATCHES);

    let spans = master.ingested_spans();
    let batches: Vec<&trace::SpanRec> = spans.iter().filter(|s| s.kind == "batch").collect();
    assert_eq!(batches.len() as u64, BATCHES, "one span per micro-batch");
    for b in &batches {
        assert_eq!(b.parent_id, 0, "batch spans are trace roots");
        assert!(b.label("rows_in").is_some() && b.label("rows_out").is_some());
        assert!(
            spans.iter().any(|s| s.kind == "job" && s.parent_id == b.span_id),
            "each batch's plan job nests under its batch span"
        );
    }
    master.shutdown();
}

#[test]
fn killed_worker_reissues_surface_in_the_job_profile() {
    let _serial = lock();
    register_ops();
    let mut c = conf(true);
    // Fast loss detection so the re-issue happens promptly.
    c.set("ignite.worker.timeout.ms", "600");
    c.set("ignite.worker.slots", "4");
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    trace::global().clear();
    let reissued0 = counter("plan.tasks.reissued");

    let plan = sc.parallelize_values_with(values(0..8), 8).map_named("obs.nap400_inc");
    let session = master.new_session();
    let job = master.submit_job(session, plan.plan()).unwrap();
    std::thread::sleep(Duration::from_millis(120));
    workers[1].kill();

    let got = master.wait_job(job, Duration::from_secs(30)).unwrap();
    assert_eq!(got, values(1..9), "result correct despite the mid-job kill");
    let reissued = counter("plan.tasks.reissued") - reissued0;
    assert!(reissued > 0, "the dead worker's in-flight tasks must be re-issued");

    // The recovery story is in the profile: one instant `event.reissue`
    // per re-issued task, parented under a span of this job's trace.
    let profile = master.job_profile(job).unwrap();
    let events = profile.spans_of_kind("event.reissue");
    assert_eq!(events.len() as u64, reissued, "one trace event per re-issued task");
    let ids: HashSet<u64> = profile.spans.iter().map(|s| s.span_id).collect();
    for e in &events {
        assert!(e.is_event(), "reissue records are instant events");
        assert!(ids.contains(&e.parent_id), "reissue events parent under their stage span");
        assert!(e.label("task").is_some() && e.label("worker").is_some());
    }
    assert!(profile.render().contains("* event.reissue"));
    master.shutdown();
}

#[test]
fn tracing_off_allocates_no_span_records_on_the_task_path() {
    let _serial = lock();
    let c = conf(false);
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    trace::global().clear();
    let counts = sc
        .parallelize_values_with(wordcount_rows(400, 100), 4)
        .reduce_by_key(2, AggSpec::SumI64)
        .collect()
        .unwrap();
    assert_eq!(counts.len(), 100);

    assert_eq!(trace::global().buffered(), 0, "no span records with tracing off");
    assert_eq!(trace::global().dropped(), 0);
    assert!(master.traced_jobs().is_empty(), "no profile collected for an untraced job");
    assert!(master.ingested_spans().is_empty());
    master.shutdown();
}
