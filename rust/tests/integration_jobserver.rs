//! Job-server integration: multi-tenant concurrent submission (results
//! bit-identical to serial execution, sessions interleaving on the slot
//! ledger), elastic workers (mid-job join, graceful drain), fine-grained
//! task recovery after a worker kill (only the lost tasks re-issue — no
//! whole-stage restart), and master-side speculative execution of
//! stragglers.

use mpignite::closure::register_op;
use mpignite::cluster::Worker;
use mpignite::config::IgniteConf;
use mpignite::jobserver::{session_task_counter, JobState};
use mpignite::prelude::*;
use std::sync::Arc;
use std::time::Duration;

/// Heartbeat-timing-sensitive clusters; serialized like the other
/// cluster suites so concurrent test threads don't turn timing
/// assumptions into flakes.
static SERIAL: std::sync::Mutex<()> = std::sync::Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

fn conf() -> IgniteConf {
    let mut c = IgniteConf::new();
    c.set("ignite.worker.heartbeat.ms", "50");
    c.set("ignite.worker.timeout.ms", "2000");
    c.set("ignite.worker.slots", "2");
    c
}

/// Per-element ops used across the scenarios. `js.inc` is pure compute;
/// the `nap` variants stretch task latency so jobs are observable (and
/// killable / drainable) mid-flight; `js.stall_inc` turns exactly the
/// partitions holding the marker value into stragglers.
fn register_ops() {
    register_op("js.inc", |v| match v {
        Value::I64(n) => Ok(Value::I64(n + 1)),
        other => Err(IgniteError::Invalid(format!("js.inc wants i64, got {}", other.type_name()))),
    });
    register_op("js.nap60_inc", |v| match v {
        Value::I64(n) => {
            std::thread::sleep(Duration::from_millis(60));
            Ok(Value::I64(n + 1))
        }
        other => Err(IgniteError::Invalid(format!("js.nap wants i64, got {}", other.type_name()))),
    });
    register_op("js.nap400_inc", |v| match v {
        Value::I64(n) => {
            std::thread::sleep(Duration::from_millis(400));
            Ok(Value::I64(n + 1))
        }
        other => Err(IgniteError::Invalid(format!("js.nap wants i64, got {}", other.type_name()))),
    });
    register_op("js.stall_inc", |v| match v {
        Value::I64(n) => {
            if n == -777 {
                std::thread::sleep(Duration::from_millis(700));
            }
            Ok(Value::I64(n + 1))
        }
        other => {
            Err(IgniteError::Invalid(format!("js.stall wants i64, got {}", other.type_name())))
        }
    });
}

fn counter(name: &str) -> u64 {
    mpignite::metrics::global().counter(name).get()
}

fn values(range: std::ops::Range<i64>) -> Vec<Value> {
    range.map(Value::I64).collect()
}

fn finished(state: u8) -> bool {
    state == JobState::Done.tag()
        || state == JobState::Failed(String::new()).tag()
        || state == JobState::Cancelled.tag()
}

#[test]
fn concurrent_sessions_interleave_and_match_serial_results() {
    let _serial = lock();
    register_ops();
    let mut c = conf();
    c.set("ignite.scheduler.policy", "fair");
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    let plan_a = sc.parallelize_values_with(values(0..8), 8).map_named("js.nap60_inc");
    let plan_b = sc.parallelize_values_with(values(100..108), 8).map_named("js.nap60_inc");

    // Serial baselines through the classic one-job-at-a-time entry point.
    let want_a: Vec<Value> = master.run_plan(plan_a.plan()).unwrap().into_iter().flatten().collect();
    let want_b: Vec<Value> = master.run_plan(plan_b.plan()).unwrap().into_iter().flatten().collect();

    let session_a = master.new_session();
    let session_b = master.new_session();
    let job_a = master.submit_job(session_a, plan_a.plan()).unwrap();
    let job_b = master.submit_job(session_b, plan_b.plan()).unwrap();

    // Watch both jobs: at some instant BOTH sessions must have completed
    // tasks while NEITHER job has finished — that is the multi-tenant
    // interleaving the fair ledger exists for (a serial master would
    // finish one job before the other completes a single task).
    let mut overlapped = false;
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let sa = master.job_status(job_a).unwrap();
        let sb = master.job_status(job_b).unwrap();
        if finished(sa.state) && finished(sb.state) {
            break;
        }
        if !finished(sa.state)
            && !finished(sb.state)
            && counter(&session_task_counter(session_a)) > 0
            && counter(&session_task_counter(session_b)) > 0
        {
            overlapped = true;
        }
        assert!(std::time::Instant::now() < deadline, "jobs did not finish in time");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(overlapped, "both sessions must progress before either job finishes");

    let got_a = master.wait_job(job_a, Duration::from_secs(5)).unwrap();
    let got_b = master.wait_job(job_b, Duration::from_secs(5)).unwrap();
    assert_eq!(got_a, want_a, "concurrent result A must be bit-identical to serial");
    assert_eq!(got_b, want_b, "concurrent result B must be bit-identical to serial");
    master.shutdown();
}

#[test]
fn worker_joining_mid_job_receives_tasks() {
    let _serial = lock();
    register_ops();
    let c = conf();
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _w1 = Worker::start(&c, master.address()).unwrap();
    master.wait_for_workers(1, Duration::from_secs(5)).unwrap();

    // 12 slow tasks over 2 slots: plenty still pending when the second
    // worker joins the running cluster.
    let plan = sc.parallelize_values_with(values(0..12), 12).map_named("js.nap60_inc");
    let session = master.new_session();
    let job = master.submit_job(session, plan.plan()).unwrap();
    std::thread::sleep(Duration::from_millis(130));
    let w2 = Worker::start(&c, master.address()).unwrap();

    let got = master.wait_job(job, Duration::from_secs(30)).unwrap();
    assert_eq!(got, values(1..13), "result unchanged by the elastic join");
    assert!(
        w2.tasks_executed() > 0,
        "the mid-job joiner must have been handed tasks (got {})",
        w2.tasks_executed()
    );
    master.shutdown();
}

#[test]
fn drained_worker_retires_gracefully_with_zero_reissues() {
    let _serial = lock();
    register_ops();
    let c = conf();
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    let reissued0 = counter("plan.tasks.reissued");
    let plan = sc.parallelize_values_with(values(0..10), 10).map_named("js.nap60_inc");
    let session = master.new_session();
    let job = master.submit_job(session, plan.plan()).unwrap();
    std::thread::sleep(Duration::from_millis(80));

    // Graceful retirement mid-job: stop placing on the worker, wait for
    // its running tasks to report. Returns only once nothing is in
    // flight there.
    master.drain_worker(workers[1].worker_id, Duration::from_secs(20)).unwrap();
    let drained_at = workers[1].tasks_executed();

    let got = master.wait_job(job, Duration::from_secs(30)).unwrap();
    assert_eq!(got, values(1..11), "job completes correctly around the drain");
    assert_eq!(
        workers[1].tasks_executed(),
        drained_at,
        "a drained worker must receive no tasks after the drain completes"
    );
    assert_eq!(
        counter("plan.tasks.reissued") - reissued0,
        0,
        "graceful drain means zero failed or re-issued tasks"
    );
    master.shutdown();
}

#[test]
fn killed_worker_reissues_only_its_unfinished_tasks() {
    let _serial = lock();
    register_ops();
    let mut c = conf();
    // Fast loss detection so the re-issue happens promptly.
    c.set("ignite.worker.timeout.ms", "600");
    c.set("ignite.worker.slots", "4");
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    let reissued0 = counter("plan.tasks.reissued");
    let retried0 = counter("cluster.plan.jobs.retried");

    // A SINGLE-stage plan (no shuffle): fine-grained recovery must
    // re-run only the dead worker's unfinished tasks — never the whole
    // stage, and never the whole job.
    let plan = sc.parallelize_values_with(values(0..8), 8).map_named("js.nap400_inc");
    let session = master.new_session();
    let job = master.submit_job(session, plan.plan()).unwrap();
    std::thread::sleep(Duration::from_millis(120));
    workers[1].kill();

    let got = master.wait_job(job, Duration::from_secs(30)).unwrap();
    assert_eq!(got, values(1..9), "result correct despite the mid-job kill");
    let reissued = counter("plan.tasks.reissued") - reissued0;
    assert!(reissued > 0, "the dead worker's in-flight tasks must be re-issued");
    assert!(
        reissued < 8,
        "fine-grained recovery: strictly fewer re-issues ({reissued}) than stage tasks (8)"
    );
    assert_eq!(
        counter("cluster.plan.jobs.retried") - retried0,
        0,
        "no whole-job (or whole-stage) restart for an in-stage worker loss"
    );
    master.shutdown();
}

#[test]
fn speculation_duplicates_straggler_without_changing_result() {
    let _serial = lock();
    register_ops();
    let mut c = conf();
    // Aggressive speculation so the injected straggler trips it fast.
    c.set("ignite.speculation.multiplier", "2.0");
    let sc = IgniteContext::cluster_driver(c.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&c, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

    let speculated0 = counter("plan.tasks.speculated");

    // Seven fast partitions establish the latency median; the marker
    // partition stalls far past multiplier x median, so the master
    // launches a duplicate on the other worker. First finisher wins;
    // the loser's late report is ignored.
    let mut rows = values(0..7);
    rows.push(Value::I64(-777));
    let plan = sc.parallelize_values_with(rows, 8).map_named("js.stall_inc");
    let session = master.new_session();
    let job = master.submit_job(session, plan.plan()).unwrap();
    let got = master.wait_job(job, Duration::from_secs(30)).unwrap();

    let mut want = values(1..8);
    want.push(Value::I64(-776));
    assert_eq!(got, want, "speculative duplicates must not change the result");
    assert!(
        counter("plan.tasks.speculated") - speculated0 >= 1,
        "the straggler must have been speculatively duplicated"
    );
    master.shutdown();
}
