//! Property-based tests (quickprop) on coordinator invariants:
//!
//! * codec round-trips for arbitrary `Value` trees;
//! * communicator `split` always yields a partition of the parent's
//!   ranks with key-ordered sub-ranks and color-consistent contexts;
//! * collectives equal their sequential oracles for random shapes;
//! * mailbox matching preserves per-channel FIFO under random interleave;
//! * RDD pipelines equal their `Vec` oracles for random data;
//! * the hash partitioner is a total, stable assignment.

use mpignite::comm::{run_local_world, Mailbox, Message, Pattern};
use mpignite::config::IgniteConf;
use mpignite::rng::Xoshiro256;
use mpignite::ser::{from_bytes, to_bytes, Value};
use mpignite::shuffle::HashPartitioner;
use mpignite::testkit::{check, FnGen, IntGen, PropConfig, VecGen};
use mpignite::IgniteContext;
use std::time::Duration;

fn cfg(cases: usize) -> PropConfig {
    PropConfig { cases, seed: 0xFEED, max_shrink: 128 }
}

// ------------------------------------------------------------- codec --

fn arbitrary_value(rng: &mut Xoshiro256, depth: usize) -> Value {
    let pick = if depth == 0 { rng.next_below(7) } else { rng.next_below(9) };
    match pick {
        0 => Value::Unit,
        1 => Value::Bool(rng.chance(0.5)),
        2 => Value::I64(rng.next_u64() as i64),
        3 => Value::F64(rng.next_f64() * 1e6 - 5e5),
        4 => Value::Str(rng.word(0, 12)),
        5 => Value::Bytes((0..rng.range(0, 16)).map(|_| rng.next_below(256) as u8).collect()),
        6 => Value::F32Vec((0..rng.range(0, 8)).map(|_| rng.next_f32()).collect()),
        7 => Value::List((0..rng.range(0, 4)).map(|_| arbitrary_value(rng, depth - 1)).collect()),
        _ => Value::Map(
            (0..rng.range(0, 4))
                .map(|i| (format!("k{i}"), arbitrary_value(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_value_codec_round_trip() {
    let gen = FnGen(|rng: &mut Xoshiro256| arbitrary_value(rng, 3));
    check(cfg(300), &gen, |v| {
        let bytes = to_bytes(v);
        let back: Value = from_bytes(&bytes).map_err(|e| e.to_string())?;
        if &back == v {
            Ok(())
        } else {
            Err(format!("decoded {back:?}"))
        }
    });
}

#[test]
fn prop_message_codec_round_trip() {
    let gen = FnGen(|rng: &mut Xoshiro256| Message {
        context: rng.next_u64(),
        src: rng.range(0, 64),
        dst_world: rng.range(0, 64),
        tag: rng.next_u64() as i64 % 1000,
        payload: arbitrary_value(rng, 2),
    });
    check(cfg(200), &gen, |m| {
        let back: Message = from_bytes(&to_bytes(m)).map_err(|e| e.to_string())?;
        if &back == m {
            Ok(())
        } else {
            Err("message changed".into())
        }
    });
}

// ------------------------------------------------------------- split --

#[test]
fn prop_split_partitions_ranks() {
    // Random world size, colors, keys: the union of sub-communicators is
    // a partition of the world, sub-ranks are dense 0..group_size, and
    // ordering follows (key, parent rank).
    #[derive(Debug, Clone)]
    struct Case {
        n: usize,
        colors: Vec<i64>,
        keys: Vec<i64>,
    }
    let gen = FnGen(|rng: &mut Xoshiro256| {
        let n = rng.range(1, 10);
        Case {
            n,
            colors: (0..n).map(|_| rng.next_below(3) as i64).collect(),
            keys: (0..n).map(|_| rng.next_u64() as i64 % 100).collect(),
        }
    });
    check(cfg(40), &gen, |case| {
        let colors = case.colors.clone();
        let keys = case.keys.clone();
        let n = case.n;
        let out = run_local_world(n, move |world| {
            let r = world.rank();
            let sub = world.split(colors[r], keys[r])?;
            Ok((sub.rank(), sub.size(), sub.context_id()))
        })
        .map_err(|e| e.to_string())?;

        // Group world ranks by color and verify.
        for color in 0..3i64 {
            let members: Vec<usize> =
                (0..n).filter(|&r| case.colors[r] == color).collect();
            if members.is_empty() {
                continue;
            }
            let mut expected = members.clone();
            expected.sort_by_key(|&r| (case.keys[r], r));
            for (expect_rank, &world_rank) in expected.iter().enumerate() {
                let (sub_rank, sub_size, _) = out[world_rank];
                if sub_rank != expect_rank {
                    return Err(format!(
                        "world rank {world_rank} got sub rank {sub_rank}, want {expect_rank}"
                    ));
                }
                if sub_size != members.len() {
                    return Err(format!("bad group size {sub_size}"));
                }
            }
            // Context ids agree within the group and differ across groups.
            let ctx0 = out[members[0]].2;
            for &m in &members {
                if out[m].2 != ctx0 {
                    return Err("context mismatch within color".into());
                }
            }
            for other in 0..3i64 {
                if other != color {
                    if let Some(&m) = (0..n).find(|&r| case.colors[r] == other).as_ref() {
                        if out[m].2 == ctx0 {
                            return Err("context collision across colors".into());
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------- collectives --

#[test]
fn prop_allreduce_equals_sequential_fold() {
    let gen = FnGen(|rng: &mut Xoshiro256| {
        let n = rng.range(1, 9);
        let values: Vec<i64> = (0..n).map(|_| rng.next_u64() as i64 % 1000).collect();
        values
    });
    check(cfg(30), &gen, |values| {
        let n = values.len();
        let vals = values.clone();
        let out = run_local_world(n, move |world| {
            world.all_reduce(vals[world.rank()], |a, b| a + b)
        })
        .map_err(|e| e.to_string())?;
        let want: i64 = values.iter().sum();
        if out.iter().all(|&v| v == want) {
            Ok(())
        } else {
            Err(format!("got {out:?}, want {want}"))
        }
    });
}

#[test]
fn prop_scan_equals_prefix_sums() {
    let gen = VecGen { inner: IntGen { lo: -50, hi: 50 }, max_len: 8 };
    check(cfg(30), &gen, |values| {
        if values.is_empty() {
            return Ok(());
        }
        let n = values.len();
        let vals = values.clone();
        let out =
            run_local_world(n, move |world| world.scan(vals[world.rank()], |a, b| a + b))
                .map_err(|e| e.to_string())?;
        let mut acc = 0;
        for (r, v) in values.iter().enumerate() {
            acc += v;
            if out[r] != acc {
                return Err(format!("rank {r}: {} != {acc}", out[r]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_gather_preserves_rank_order() {
    let gen = FnGen(|rng: &mut Xoshiro256| rng.range(1, 10));
    check(cfg(20), &gen, |&n| {
        let out = run_local_world(n, move |world| world.gather(0, world.rank() as i64))
            .map_err(|e| e.to_string())?;
        let want: Vec<i64> = (0..n as i64).collect();
        match &out[0] {
            Some(v) if *v == want => Ok(()),
            other => Err(format!("root got {other:?}")),
        }
    });
}

// ----------------------------------------------------------- mailbox --

#[test]
fn prop_mailbox_fifo_per_channel_random_interleave() {
    // Random sequence of (channel, value) deliveries; receives per channel
    // must observe values in delivery order regardless of interleaving.
    #[derive(Debug, Clone)]
    struct Case {
        events: Vec<(usize, i64)>, // (channel 0..3, value)
    }
    let gen = FnGen(|rng: &mut Xoshiro256| {
        let n = rng.range(1, 40);
        let mut next_val = [0i64; 3];
        Case {
            events: (0..n)
                .map(|_| {
                    let ch = rng.range(0, 3);
                    let v = next_val[ch];
                    next_val[ch] += 1;
                    (ch, v)
                })
                .collect(),
        }
    });
    check(cfg(100), &gen, |case| {
        let mb = Mailbox::new(1 << 20);
        for &(ch, v) in &case.events {
            mb.deliver(Message {
                context: 0,
                src: ch,
                dst_world: 0,
                tag: 0,
                payload: Value::I64(v),
            });
        }
        for ch in 0..3usize {
            let expected: Vec<i64> =
                case.events.iter().filter(|(c, _)| *c == ch).map(|(_, v)| *v).collect();
            for want in expected {
                let got: i64 = mb
                    .recv_blocking(
                        Pattern { context: 0, src: ch as i64, tag: 0 },
                        Duration::from_millis(100),
                    )
                    .map_err(|e| e.to_string())?;
                if got != want {
                    return Err(format!("channel {ch}: got {got}, want {want}"));
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------- rdd ----

#[test]
fn prop_rdd_pipeline_equals_vec_oracle() {
    let gen = VecGen { inner: IntGen { lo: -1000, hi: 1000 }, max_len: 200 };
    check(cfg(25), &gen, |data| {
        let sc = IgniteContext::local(4);
        let got: Vec<i64> = sc
            .parallelize_with(data.clone(), 5)
            .map(|x| x * 2)
            .filter(|x| x % 3 != 0)
            .collect()
            .map_err(|e| e.to_string())?;
        let want: Vec<i64> =
            data.iter().map(|x| x * 2).filter(|x| x % 3 != 0).collect();
        if got == want {
            Ok(())
        } else {
            Err(format!("{} vs {} elements", got.len(), want.len()))
        }
    });
}

#[test]
fn prop_reduce_by_key_equals_hashmap_oracle() {
    let gen = VecGen { inner: IntGen { lo: 0, hi: 500 }, max_len: 150 };
    check(cfg(20), &gen, |data| {
        let sc = IgniteContext::local(4);
        let pairs: Vec<(i64, i64)> = data.iter().map(|&x| (x % 7, x)).collect();
        let got = sc
            .parallelize(pairs.clone())
            .reduce_by_key(3, |a, b| a + b)
            .collect_map()
            .map_err(|e| e.to_string())?;
        let mut want = std::collections::HashMap::new();
        for (k, v) in pairs {
            *want.entry(k).or_insert(0) += v;
        }
        if got == want {
            Ok(())
        } else {
            Err(format!("{got:?} vs {want:?}"))
        }
    });
}

#[test]
fn prop_reduce_by_key_identical_across_spill_budgets() {
    // The tiered shuffle pipeline must be invisible to results: budget 0
    // (every bucket spills to disk), the default budget, and usize::MAX
    // (nothing ever spills) all produce the same reduce_by_key output.
    let gen = VecGen { inner: IntGen { lo: 0, hi: 400 }, max_len: 120 };
    check(cfg(8), &gen, |data| {
        let pairs: Vec<(i64, i64)> = data.iter().map(|&x| (x % 11, x)).collect();
        let budgets =
            ["0".to_string(), "67108864".to_string(), usize::MAX.to_string()];
        let mut results = Vec::new();
        for budget in &budgets {
            let mut conf = IgniteConf::new();
            conf.set("ignite.worker.slots", "4");
            conf.set("ignite.shuffle.memory.bytes", budget.clone());
            let sc = IgniteContext::with_conf(conf).map_err(|e| e.to_string())?;
            let got = sc
                .parallelize_with(pairs.clone(), 5)
                .reduce_by_key(3, |a, b| a + b)
                .collect_map()
                .map_err(|e| e.to_string())?;
            if budget == "0" && !pairs.is_empty() {
                if sc.engine().shuffle.spilled_count() == 0 {
                    return Err("budget 0 did not spill".into());
                }
            }
            if budget == &usize::MAX.to_string()
                && sc.engine().shuffle.spilled_count() != 0
            {
                return Err("unbounded budget spilled".into());
            }
            results.push(got);
        }
        if results[0] == results[1] && results[1] == results[2] {
            Ok(())
        } else {
            Err(format!(
                "spill tiers diverged: all-spill {:?} vs default {:?} vs in-memory {:?}",
                results[0], results[1], results[2]
            ))
        }
    });
}

#[test]
fn prop_results_invariant_under_compression_and_lru_budgets() {
    // The whole shuffle fast path must be invisible to results:
    // compression on/off × LRU memory budget {0 = all-spill, tiny =
    // forced eviction churn, usize::MAX = never spill} all produce
    // bit-identical reduce_by_key output. (Batched vs per-bucket remote
    // fetch is the cluster-mode leg of this invariant, covered in
    // integration_shuffle.rs.)
    let gen = VecGen { inner: IntGen { lo: 0, hi: 400 }, max_len: 120 };
    check(cfg(6), &gen, |data| {
        let pairs: Vec<(i64, i64)> = data.iter().map(|&x| (x % 9, x)).collect();
        let budgets = ["0".to_string(), "512".to_string(), usize::MAX.to_string()];
        let mut results = Vec::new();
        for compress in ["false", "true"] {
            for budget in &budgets {
                let mut conf = IgniteConf::new();
                conf.set("ignite.worker.slots", "4");
                conf.set("ignite.shuffle.compress", compress);
                conf.set("ignite.shuffle.memory.bytes", budget.clone());
                let sc = IgniteContext::with_conf(conf).map_err(|e| e.to_string())?;
                let got = sc
                    .parallelize_with(pairs.clone(), 5)
                    .reduce_by_key(3, |a, b| a + b)
                    .collect_map()
                    .map_err(|e| e.to_string())?;
                results.push((compress, budget.clone(), got));
            }
        }
        let (_, _, reference) = &results[0];
        for (compress, budget, got) in &results[1..] {
            if got != reference {
                return Err(format!(
                    "compress={compress} budget={budget} diverged: {got:?} vs {reference:?}"
                ));
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------- partitioner --

#[test]
fn prop_partitioner_total_and_stable() {
    let gen = FnGen(|rng: &mut Xoshiro256| (rng.range(1, 33), rng.next_u64()));
    check(cfg(200), &gen, |&(parts, key)| {
        let p = HashPartitioner::new(parts);
        let a = p.partition(&key);
        let b = p.partition(&key);
        if a != b {
            return Err("unstable".into());
        }
        if a >= parts {
            return Err(format!("{a} out of range {parts}"));
        }
        Ok(())
    });
}
