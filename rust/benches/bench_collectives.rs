//! E3 — collective scaling: broadcast and allReduce across algorithms
//! (linear / binomial tree / block-store / ring) and rank counts.
//!
//! Expected shape: tree beats linear as ranks grow (log vs linear rounds
//! at the root); block-store broadcast (the paper's "Spark built-in
//! broadcasting" alternative) wins for large payloads in-process; ring
//! allreduce pays 2(N−1) hops but each hop is cheap.

use mpignite::bench::time_world_op;
use mpignite::comm::CollectiveAlgo;
use mpignite::util::{fmt_bytes, fmt_duration, Table};

fn main() {
    mpignite::util::init_logger();
    let fast = std::env::var("MPIGNITE_BENCH_FAST").is_ok();
    let iters = if fast { 20 } else { 200 };

    // ---- broadcast ----------------------------------------------------
    println!("\n== E3a: broadcast latency by algorithm ==");
    let mut t = Table::new(vec!["ranks", "payload", "linear", "tree", "blockstore"]);
    let mut csv = Table::new(vec!["ranks", "payload", "linear_ns", "tree_ns", "blockstore_ns"]);
    for n in [4usize, 8, 16, 32] {
        for payload in [8usize, 8192] {
            let mut cells = vec![n.to_string(), fmt_bytes(payload as u64)];
            let mut raw = vec![n.to_string(), payload.to_string()];
            for algo in [CollectiveAlgo::Linear, CollectiveAlgo::Tree, CollectiveAlgo::BlockStore] {
                let words = payload / 8;
                let d = time_world_op(n, iters, move |comm, _| {
                    let data = if comm.rank() == 0 {
                        Some(vec![1.0f64; words])
                    } else {
                        None
                    };
                    let _ = comm.broadcast_with(algo, 0, data).unwrap();
                });
                cells.push(fmt_duration(d));
                raw.push(d.as_nanos().to_string());
            }
            t.row(cells);
            csv.row(raw);
        }
    }
    print!("{}", t.render());
    println!("\n-- csv --\n{}", csv.to_csv());

    // ---- allReduce ----------------------------------------------------
    println!("== E3b: allReduce(sum of f64 vectors) latency by algorithm ==");
    let mut t = Table::new(vec!["ranks", "payload", "linear", "tree", "ring"]);
    let mut csv = Table::new(vec!["ranks", "payload", "linear_ns", "tree_ns", "ring_ns"]);
    for n in [4usize, 8, 16, 32] {
        for payload in [8usize, 8192] {
            let mut cells = vec![n.to_string(), fmt_bytes(payload as u64)];
            let mut raw = vec![n.to_string(), payload.to_string()];
            for algo in [CollectiveAlgo::Linear, CollectiveAlgo::Tree, CollectiveAlgo::Ring] {
                let words = payload / 8;
                let d = time_world_op(n, iters, move |comm, _| {
                    let mine = vec![comm.rank() as f64; words];
                    let _ = comm
                        .all_reduce_with(algo, mine, |a, b| {
                            a.iter().zip(&b).map(|(x, y)| x + y).collect()
                        })
                        .unwrap();
                });
                cells.push(fmt_duration(d));
                raw.push(d.as_nanos().to_string());
            }
            t.row(cells);
            csv.row(raw);
        }
    }
    print!("{}", t.render());
    println!("\n-- csv --\n{}", csv.to_csv());
}
