//! E13 — zero-copy comm plane: (a) assembled vs vectored (scatter-gather)
//! framing for a `fetch_multi`-shaped multi-bucket response — the
//! assembled lane copies every bucket into one contiguous frame buffer
//! before writing, the vectored lane hands the shared bucket bytes to the
//! socket as borrowed segments; (b) blocking vs non-blocking allreduce
//! when each iteration also has compute to do — `i_all_reduce` overlaps
//! the collective with the compute, so the iteration costs
//! ~max(compute, allreduce) instead of their sum.
//!
//! Run: `cargo bench --bench bench_comm` (MPIGNITE_BENCH_FAST=1 to
//! smoke). CSV block feeds EXPERIMENTS.md baselines.

use mpignite::bench::{black_box, BenchSuite, Throughput};
use mpignite::comm::run_local_world;
use mpignite::metrics;
use mpignite::prelude::*;
use mpignite::rpc::{Envelope, RpcBody, RpcEnv, Segment};
use std::sync::Arc;
use std::time::Duration;

/// Buckets per simulated `fetch_multi` response frame.
const BUCKETS: usize = 16;
/// Bytes per bucket.
const BUCKET_BYTES: usize = 64 * 1024;

const RANKS: usize = 4;
const ITERS: usize = 8;
/// Per-iteration compute kernel size (f64 mul-adds).
const WORK: usize = 200_000;

/// A `ShuffleFetchMultiResp`-shaped scatter-gather body: codec
/// scaffolding in owned head segments, each bucket's shared bytes as a
/// borrowed segment between them (what the worker's shuffle service
/// sends on the vectored path).
fn segmented_body(buckets: &[Arc<Vec<u8>>]) -> RpcBody {
    let mut head = Vec::new();
    mpignite::ser::put_varint(&mut head, buckets.len() as u64);
    let mut segments: Vec<Segment> = Vec::with_capacity(buckets.len() * 2);
    for (m, bucket) in buckets.iter().enumerate() {
        head.extend_from_slice(&(m as u64).to_le_bytes());
        head.push(1); // Option tag: Some
        mpignite::ser::put_varint(&mut head, bucket.len() as u64);
        segments.push(Segment::Owned(std::mem::take(&mut head)));
        segments.push(Segment::Shared(bucket.clone()));
    }
    RpcBody::Segments(segments)
}

/// The per-iteration compute kernel both allreduce lanes run.
fn compute(rank: usize) -> f64 {
    let mut acc = 0.0f64;
    let mut x = 1.0 + rank as f64 * 1e-3;
    for _ in 0..WORK {
        x = x * 1.000_000_1 + 1e-9;
        acc += x;
    }
    black_box(acc)
}

fn main() {
    mpignite::util::init_logger();
    let mut suite = BenchSuite::new(format!(
        "E13: zero-copy comm plane ({BUCKETS} x {} KiB buckets/frame; \
         {RANKS} ranks, {ITERS} iterations, {WORK} mul-adds compute)",
        BUCKET_BYTES / 1024
    ));

    // ---- (a) assembled vs vectored multi-bucket response framing ----
    let server = RpcEnv::server("bench-comm-server", 0).unwrap();
    let buckets: Vec<Arc<Vec<u8>>> = (0..BUCKETS)
        .map(|i| Arc::new(vec![(i % 251) as u8; BUCKET_BYTES]))
        .collect();
    {
        let buckets = buckets.clone();
        server.register(
            "fetch",
            Arc::new(move |_env: &Envelope| Ok(Some(segmented_body(&buckets)))),
        );
    }
    let addr = server.address();
    let total = (BUCKETS * BUCKET_BYTES) as u64;

    {
        // Assembled lane: the reply's segments are flattened into one
        // contiguous frame buffer before the write (the pre-vectored
        // behavior, and the `MPIGNITE_RPC_VECTORED=false` CI lane).
        server.set_vectored(false);
        let client = RpcEnv::client("bench-comm-assembled");
        let addr = addr.clone();
        let _ = client.ask(&addr, "fetch", Vec::new(), Duration::from_secs(5)).unwrap();
        suite.bench_throughput("fetch_multi_assembled", Throughput::Bytes(total), move || {
            let resp =
                client.ask(&addr, "fetch", Vec::new(), Duration::from_secs(5)).unwrap();
            black_box(resp.len());
        });
    }
    {
        // Vectored lane: bucket bytes go buffer→wire as borrowed
        // segments; only the headers are materialized.
        server.set_vectored(true);
        let client = RpcEnv::client("bench-comm-vectored");
        let addr = addr.clone();
        let _ = client.ask(&addr, "fetch", Vec::new(), Duration::from_secs(5)).unwrap();
        let zc_before = metrics::global().counter("rpc.bytes.zero_copy").get();
        suite.bench_throughput("fetch_multi_vectored", Throughput::Bytes(total), move || {
            let resp =
                client.ask(&addr, "fetch", Vec::new(), Duration::from_secs(5)).unwrap();
            black_box(resp.len());
        });
        let zc = metrics::global().counter("rpc.bytes.zero_copy").get() - zc_before;
        println!("vectored lane: {zc} B shipped zero-copy");
    }
    server.shutdown();

    // ---- (b) blocking vs non-blocking allreduce with compute ----
    suite.bench("allreduce_blocking_then_compute", || {
        let sums = run_local_world(RANKS, |comm: &SparkComm| {
            let mut acc = 0.0f64;
            for _ in 0..ITERS {
                let local = compute(comm.rank());
                acc += comm.all_reduce(local, |a, b| a + b)?;
            }
            Ok(acc)
        })
        .unwrap();
        black_box(sums);
    });
    suite.bench("allreduce_overlapped_with_compute", || {
        let sums = run_local_world(RANKS, |comm: &SparkComm| {
            let mut acc = 0.0f64;
            let mut local = compute(comm.rank());
            for it in 0..ITERS {
                // Start the collective on the current value, then do the
                // NEXT iteration's compute while it runs.
                let fut = comm.i_all_reduce(local, |a, b| a + b)?;
                if it + 1 < ITERS {
                    local = compute(comm.rank());
                }
                acc += fut.wait()?;
            }
            Ok(acc)
        })
        .unwrap();
        black_box(sums);
    });

    suite.report();
    let results = suite.results();
    let assembled = results[0].median;
    let vectored = results[1].median;
    let blocking = results[2].median;
    let overlapped = results[3].median;
    println!(
        "\nframing: assembled/vectored = {:.2}x; allreduce: blocking/overlapped = {:.2}x \
         (overlapped collectives started: {})",
        assembled.as_secs_f64() / vectored.as_secs_f64(),
        blocking.as_secs_f64() / overlapped.as_secs_f64(),
        metrics::global().counter("comm.collectives.overlapped").get()
    );
}
