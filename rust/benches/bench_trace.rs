//! E15 — tracing overhead: what the span plane costs when it is off
//! (the product-default hot path), when it is on, and end-to-end on a
//! real 2-worker plan job.
//!
//! Expected shape: off-path span creation is nanoseconds (one relaxed
//! atomic load, no allocation); on-path costs one clock read plus one
//! ring push per span; whole-job overhead with tracing on stays within
//! a few percent of the untraced run.
//!
//! Run: `cargo bench --bench bench_trace` (MPIGNITE_BENCH_FAST=1 to
//! smoke).

use mpignite::cluster::Worker;
use mpignite::config::IgniteConf;
use mpignite::rdd::AggSpec;
use mpignite::ser::Value;
use mpignite::trace;
use mpignite::util::{fmt_duration, Stopwatch, Table};
use mpignite::IgniteContext;
use std::sync::Arc;
use std::time::Duration;

fn span_iters() -> u64 {
    if std::env::var("MPIGNITE_BENCH_FAST").is_ok() {
        50_000
    } else {
        1_000_000
    }
}

fn job_rows() -> usize {
    if std::env::var("MPIGNITE_BENCH_FAST").is_ok() {
        2_000
    } else {
        20_000
    }
}

/// Per-op cost of `span(...) -> label -> finish` at the current tracer
/// state, in nanoseconds.
fn span_cost_ns(parent: Option<trace::TraceContext>) -> f64 {
    let iters = span_iters();
    let sw = Stopwatch::start();
    for i in 0..iters {
        let mut s = trace::span("bench", parent);
        s.label("i", i.to_string());
        s.finish();
    }
    let ns = sw.elapsed().as_nanos() as f64 / iters as f64;
    trace::global().clear();
    ns
}

fn event_cost_ns(parent: Option<trace::TraceContext>) -> f64 {
    let iters = span_iters();
    let sw = Stopwatch::start();
    for i in 0..iters {
        trace::event(parent, "bench.event", &[("i", i.to_string())]);
    }
    let ns = sw.elapsed().as_nanos() as f64 / iters as f64;
    trace::global().clear();
    ns
}

/// One 2-worker distributed word count (4 maps × 8 reduces over the
/// shuffle plane); returns its wall time.
fn cluster_job(traced: bool) -> Duration {
    let mut conf = IgniteConf::new();
    conf.set("ignite.worker.heartbeat.ms", "50");
    conf.set("ignite.trace.enabled", if traced { "true" } else { "false" });
    let sc = IgniteContext::cluster_driver(conf.clone(), 0).unwrap();
    let master = sc.master().unwrap().clone();
    let _workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&conf, master.address()).unwrap()).collect();
    master.wait_for_workers(2, Duration::from_secs(10)).unwrap();
    let rows: Vec<Value> = (0..job_rows())
        .map(|i| Value::List(vec![Value::Str(format!("word{}", i % 500)), Value::I64(1)]))
        .collect();
    let sw = Stopwatch::start();
    let counts = sc
        .parallelize_values_with(rows, 4)
        .reduce_by_key(8, AggSpec::SumI64)
        .collect()
        .unwrap();
    let elapsed = sw.elapsed();
    assert_eq!(counts.len(), 500);
    master.shutdown();
    trace::global().set_enabled(false);
    trace::global().clear();
    elapsed
}

fn main() {
    mpignite::util::init_logger();
    println!("\n== E15: tracing overhead ==");
    let mut t = Table::new(vec!["scenario", "cost", "notes"]);

    // Hot-path primitive costs, tracing OFF: every span/event is a
    // no-op gated on one atomic load — no SpanRec is ever allocated.
    trace::global().set_enabled(false);
    let off_none = span_cost_ns(None);
    let off_ctx = span_cost_ns(Some(trace::TraceContext { trace_id: 1, span_id: 1 }));
    t.row(vec![
        "span create+finish, trace OFF, no parent".into(),
        format!("{off_none:.1} ns/op"),
        "product default".into(),
    ]);
    t.row(vec![
        "span create+finish, trace OFF, parent ctx".into(),
        format!("{off_ctx:.1} ns/op"),
        String::new(),
    ]);

    // Tracing ON: clock read + label alloc + ring push.
    trace::global().set_enabled(true);
    trace::global().set_sample_rate(1.0);
    let on_ctx = span_cost_ns(Some(trace::TraceContext { trace_id: 1, span_id: 1 }));
    let on_event = event_cost_ns(Some(trace::TraceContext { trace_id: 1, span_id: 1 }));
    trace::global().set_enabled(false);
    t.row(vec![
        "span create+finish, trace ON".into(),
        format!("{on_ctx:.1} ns/op"),
        "clock + ring push".into(),
    ]);
    t.row(vec![
        "instant event, trace ON".into(),
        format!("{on_event:.1} ns/op"),
        String::new(),
    ]);

    // End-to-end: the same 2-worker job untraced vs fully traced.
    let base = cluster_job(false);
    let traced = cluster_job(true);
    let overhead = (traced.as_secs_f64() / base.as_secs_f64() - 1.0) * 100.0;
    t.row(vec!["2-worker word-count, trace OFF".into(), fmt_duration(base), String::new()]);
    t.row(vec![
        "2-worker word-count, trace ON".into(),
        fmt_duration(traced),
        format!("{overhead:+.1}% vs off"),
    ]);

    print!("{}", t.render());
    println!("\nbench_trace OK");
}
