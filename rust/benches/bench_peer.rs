//! E12 — peer sections vs shuffle: one k-means step per iteration as an
//! **in-stage allreduce** (a single gang-scheduled peer section runs all
//! iterations, exchanging centroid stats through `all_reduce` between
//! sibling tasks) versus the classic Spark shape (one plan job per
//! iteration: map-assign → `reduce_by_key` shuffle → driver recomputes
//! centroids → next job).
//!
//! Both lanes run the same k-means math over the same points on a real
//! 2-worker in-process cluster. Expected shape: the peer lane wins and
//! its margin grows with the iteration count, because it pays gang
//! launch ONCE and then only ~k·d floats of allreduce per iteration,
//! while the shuffle lane pays stage shipping + bucket registration +
//! fetch + driver round-trip per iteration — the pattern Alchemist pays
//! a Spark⇔MPI bridge for and DataMPI shows is the performance-critical
//! shape.
//!
//! Run: `cargo bench --bench bench_peer` (MPIGNITE_BENCH_FAST=1 to
//! smoke). CSV block feeds CHANGES.md baselines.

use mpignite::apps;
use mpignite::bench::{black_box, BenchSuite};
use mpignite::closure::register_op;
use mpignite::cluster::Worker;
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use std::sync::{Arc, Mutex};
use std::time::Duration;

const POINTS: usize = 400;
const PARTS: usize = 4;
const K: usize = 3;
const ITERS: usize = 3;

fn points() -> Vec<Value> {
    (0..POINTS)
        .map(|i| {
            let center = match i % 3 {
                0 => (0.0, 0.0),
                1 => (10.0, 0.0),
                _ => (0.0, 10.0),
            };
            let jitter = 0.3 * ((i * 7 % 13) as f64 / 13.0 - 0.5);
            Value::F64Vec(vec![center.0 + jitter, center.1 - jitter])
        })
        .collect()
}

/// Shared centroid cell for the shuffle lane: the assign op reads it,
/// the driver writes it between iterations. (In-process clusters share
/// the registry; a multi-process deployment would broadcast the
/// centroids instead — which is exactly the overhead this lane models.)
fn centroid_cell() -> &'static Mutex<Vec<Vec<f64>>> {
    static CELL: Mutex<Vec<Vec<f64>>> = Mutex::new(Vec::new());
    &CELL
}

fn register_ops() {
    apps::register_kmeans_peer("bench.peer.kmeans", K, ITERS);
    // point -> List([I64(cluster), F64Vec(coordinate sums + count)])
    register_op("bench.peer.assign", |v| {
        let Value::F64Vec(p) = v else {
            return Err(IgniteError::Invalid("assign wants f64vec".into()));
        };
        let centroids = centroid_cell().lock().unwrap().clone();
        let mut best = 0usize;
        let mut best_dist = f64::INFINITY;
        for (j, c) in centroids.iter().enumerate() {
            let dist: f64 = c.iter().zip(&p).map(|(a, b)| (a - b) * (a - b)).sum();
            if dist < best_dist {
                best_dist = dist;
                best = j;
            }
        }
        let mut stats = p.clone();
        stats.push(1.0);
        Ok(Value::List(vec![Value::I64(best as i64), Value::F64Vec(stats)]))
    });
    // List([a, b]) -> elementwise sum (the shuffle-side combiner).
    register_op("bench.peer.merge", |v| {
        let Value::List(mut ab) = v else {
            return Err(IgniteError::Invalid("merge wants List([a, b])".into()));
        };
        let (Some(Value::F64Vec(b)), Some(Value::F64Vec(mut a))) = (ab.pop(), ab.pop()) else {
            return Err(IgniteError::Invalid("merge wants f64vec stats".into()));
        };
        for (ai, bi) in a.iter_mut().zip(&b) {
            *ai += bi;
        }
        Ok(Value::F64Vec(a))
    });
}

fn cluster() -> (IgniteContext, Vec<Arc<Worker>>) {
    let mut conf = IgniteConf::new();
    conf.set("ignite.worker.heartbeat.ms", "50");
    let sc = IgniteContext::cluster_driver(conf.clone(), 0).expect("driver");
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&conf, master.address()).expect("worker")).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();
    (sc, workers)
}

/// One full k-means run, peer-section flavor: ONE gang, all iterations
/// inside the stage.
fn run_peer(sc: &IgniteContext) -> usize {
    sc.peer_rdd(points(), PARTS, "bench.peer.kmeans").collect().expect("peer job").len()
}

/// One full k-means run, shuffle flavor: one plan job per iteration,
/// centroids recomputed on the driver in between.
fn run_shuffle(sc: &IgniteContext) -> usize {
    let initial: Vec<Vec<f64>> =
        (0..K).map(|j| vec![j as f64 * 5.0, j as f64 * 5.0]).collect();
    *centroid_cell().lock().unwrap() = initial;
    let mut last = 0;
    for _ in 0..ITERS {
        let reduced = sc
            .parallelize_values_with(points(), PARTS)
            .map_named("bench.peer.assign")
            .reduce_by_key(1, AggSpec::Named { name: "bench.peer.merge".into() })
            .collect()
            .expect("shuffle job");
        let mut centroids = centroid_cell().lock().unwrap();
        for row in &reduced {
            let Value::List(pair) = row else { continue };
            let (Some(Value::I64(j)), Some(Value::F64Vec(stats))) =
                (pair.first(), pair.get(1))
            else {
                continue;
            };
            let d = stats.len() - 1;
            let count = stats[d];
            if count > 0.0 {
                centroids[*j as usize] = stats[..d].iter().map(|x| x / count).collect();
            }
        }
        last = reduced.len();
    }
    last
}

fn main() {
    mpignite::util::init_logger();
    register_ops();
    let mut suite = BenchSuite::new(format!(
        "E12: k-means step, allreduce-in-stage vs reduce_by_key shuffle \
         ({POINTS} points, {PARTS} ranks, k={K}, {ITERS} iterations, 2 workers)"
    ));

    {
        let (sc, _workers) = cluster();
        suite.bench("kmeans_allreduce_in_stage", || {
            black_box(run_peer(&sc));
        });
        let sent = mpignite::metrics::global().counter("peer.bytes.sent").get();
        println!("peer lane: {sent} B of in-stage peer traffic total");
        sc.master().unwrap().shutdown();
    }

    {
        let (sc, _workers) = cluster();
        suite.bench("kmeans_shuffle_per_iteration", || {
            black_box(run_shuffle(&sc));
        });
        let fetches = mpignite::metrics::global().counter("shuffle.remote.fetches").get();
        println!("shuffle lane: {fetches} remote bucket fetches total");
        sc.master().unwrap().shutdown();
    }

    suite.report();
}
