//! E1 — point-to-point latency/throughput: master-relay vs peer-to-peer
//! (the paper's two implementation iterations, §3.1), over real TCP with
//! a real master process in the relay path. Also sweeps message size on
//! the local transport and compares the two mailbox paths (receive
//! posted first vs message buffered first).
//!
//! Expected shape: p2p < relay at every size, gap grows with message size
//! (relay pays serialize+forward twice); the paper's design switched to
//! p2p for exactly this reason.

use mpignite::cluster::{Master, Worker};
use mpignite::comm::{run_local_world, TransportMode};
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use mpignite::util::{fmt_bytes, fmt_duration, Table};
use std::time::{Duration, Instant};

fn cluster_pingpong(mode: &str, payload: usize, iters: usize) -> Duration {
    let fn_name = format!("bench.pingpong.{mode}.{payload}");
    let iters_i = iters as i64;
    mpignite::closure::register_parallel_fn(&fn_name, move |comm, arg| {
        let bytes = match arg {
            Value::I64(n) => vec![0u8; *n as usize],
            _ => vec![],
        };
        comm.barrier()?;
        let t0 = Instant::now();
        for i in 0..iters_i {
            let tag = i % 100;
            if comm.rank() == 0 {
                comm.send(1, tag, bytes.clone())?;
                let _: Vec<u8> = comm.receive(1, tag)?;
            } else {
                let b: Vec<u8> = comm.receive(0, tag)?;
                comm.send(0, tag, b)?;
            }
        }
        Ok(Value::F64(t0.elapsed().as_secs_f64() / iters_i as f64))
    });

    let mut conf = IgniteConf::new();
    conf.set("ignite.comm.mode", mode);
    conf.set("ignite.comm.recv.timeout.ms", "120000");
    let master = Master::start(&conf, 0).unwrap();
    let _w1 = Worker::start(&conf, master.address()).unwrap();
    let _w2 = Worker::start(&conf, master.address()).unwrap();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();
    let out = master.execute_named(&fn_name, 2, Value::I64(payload as i64)).unwrap();
    master.shutdown();
    match out[0] {
        Value::F64(s) => Duration::from_secs_f64(s),
        _ => panic!("bad bench result"),
    }
}

fn main() {
    mpignite::util::init_logger();
    let fast = std::env::var("MPIGNITE_BENCH_FAST").is_ok();
    let iters = if fast { 30 } else { 300 };

    // ---- relay vs p2p over TCP (2 workers) ---------------------------
    println!("\n== E1: relay vs p2p round-trip over TCP (2 ranks on 2 workers) ==");
    let mut t = Table::new(vec!["payload", "relay RTT", "p2p RTT", "relay/p2p"]);
    let mut csv = Table::new(vec!["payload_bytes", "relay_ns", "p2p_ns"]);
    for payload in [8usize, 1024, 16 * 1024, 256 * 1024] {
        let relay = cluster_pingpong("relay", payload, iters);
        let p2p = cluster_pingpong("p2p", payload, iters);
        let ratio = relay.as_secs_f64() / p2p.as_secs_f64();
        t.row(vec![
            fmt_bytes(payload as u64),
            fmt_duration(relay),
            fmt_duration(p2p),
            format!("{ratio:.2}x"),
        ]);
        csv.row(vec![
            payload.to_string(),
            relay.as_nanos().to_string(),
            p2p.as_nanos().to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("\n-- csv --\n{}", csv.to_csv());

    // ---- local transport: matching-path ablation ----------------------
    // posted-first (receiver waits) vs buffered-first (sender races ahead
    // and the unexpected queue absorbs it — the paper's receiver-side
    // buffering).
    println!("== E1b: mailbox path ablation (local, 2 ranks, 8 B) ==");
    let mut t = Table::new(vec!["path", "round trip"]);
    for (name, recv_first) in [("posted-receive-first", true), ("buffered-first", false)] {
        let iters = if fast { 200 } else { 2000 };
        let out = run_local_world(2, move |comm| {
            comm.barrier()?;
            let t0 = Instant::now();
            for i in 0..iters {
                let tag = (i % 100) as i64;
                if comm.rank() == 0 {
                    if recv_first {
                        // Post receive, then nudge: peer replies after.
                        let f = comm.receive_async::<i64>(1, tag)?;
                        comm.send(1, tag, 1i64)?;
                        let _ = f.wait()?;
                    } else {
                        comm.send(1, tag, 1i64)?;
                        // Delay our receive so the reply lands in the
                        // unexpected queue first.
                        std::thread::yield_now();
                        let _: i64 = comm.receive(1, tag)?;
                    }
                } else {
                    let _: i64 = comm.receive(0, tag)?;
                    comm.send(0, tag, 2i64)?;
                }
            }
            Ok(t0.elapsed() / iters as u32)
        })
        .unwrap();
        t.row(vec![name.to_string(), fmt_duration(out[0])]);
    }
    print!("{}", t.render());
}
