//! E5 — closure model vs plain RDD on the same computation (the paper's
//! own observation that Listing 1 "could have equivalently been written
//! with traditional RDDs and a mapping function").
//!
//! Workload: 128×512 matvec, row-parallel. Expected shape: both models
//! are within a small constant of each other for compute-bound work —
//! the closure model's overhead is rank/world setup, the RDD model's is
//! scheduler bookkeeping.
//!
//! The `plan_ir_decoded` lane tracks the serializable plan IR's
//! interpretation overhead: the same matvec expressed as a `PlanSpec`
//! (named dot-product op + built-in `SumF64`), freshly decoded from its
//! wire encoding each iteration — i.e. exactly what a worker executing a
//! shipped stage pays, minus the network.

use mpignite::bench::{black_box, BenchSuite, Throughput};
use mpignite::prelude::*;
use mpignite::ser::from_bytes;
use std::sync::Arc;

const ROWS: usize = 128;
const COLS: usize = 512;

fn matrix() -> Vec<Vec<f64>> {
    (0..ROWS)
        .map(|i| (0..COLS).map(|j| ((i * 31 + j * 17) % 1000) as f64 / 1000.0).collect())
        .collect()
}

fn main() {
    mpignite::util::init_logger();
    let sc = IgniteContext::local(4);
    let mat = Arc::new(matrix());
    let x: Arc<Vec<f64>> = Arc::new((0..COLS).map(|j| (j % 7) as f64).collect());

    let mut suite = BenchSuite::new("E5: RDD map/reduce vs parallel closure (128x512 matvec)");

    // --- data parallel: RDD of rows, map to dot products, sum ---------
    {
        let mat = mat.clone();
        let x = x.clone();
        let sc_rdd = IgniteContext::local(4);
        suite.bench_throughput("rdd_map_reduce", Throughput::Items(ROWS as u64), move || {
            let rows: Vec<Vec<f64>> = (*mat).clone();
            let x = x.clone();
            let total: f64 = sc_rdd
                .parallelize(rows)
                .map(move |row| row.iter().zip(x.iter()).map(|(a, b)| a * b).sum::<f64>())
                .reduce(|a, b| a + b)
                .unwrap();
            black_box(total);
        });
    }

    // --- task parallel: parallel closure, one row block per rank ------
    {
        let mat = mat.clone();
        let x = x.clone();
        let sc2 = sc;
        suite.bench_throughput("parallel_closure", Throughput::Items(ROWS as u64), move || {
            let mat = mat.clone();
            let x = x.clone();
            let partials = sc2
                .parallelize_func(move |world: &SparkComm| {
                    let per = ROWS / world.size();
                    let r0 = world.rank() * per;
                    let local: f64 = (r0..r0 + per)
                        .map(|i| mat[i].iter().zip(x.iter()).map(|(a, b)| a * b).sum::<f64>())
                        .sum();
                    world.all_reduce(local, |a, b| a + b).unwrap()
                })
                .execute(4)
                .unwrap();
            black_box(partials[0]);
        });
    }

    // --- plan IR: decoded-plan execution on the same workload ----------
    {
        let x_dot = x.clone();
        register_op("bench.dot", move |v| match v {
            Value::F64Vec(row) => {
                Ok(Value::F64(row.iter().zip(x_dot.iter()).map(|(a, b)| a * b).sum()))
            }
            other => Err(IgniteError::Invalid(format!(
                "bench.dot wants f64vec, got {}",
                other.type_name()
            ))),
        });
        let sc_plan = IgniteContext::local(4);
        let rows: Vec<Value> = mat.iter().map(|row| Value::F64Vec(row.clone())).collect();
        let plan_bytes = sc_plan
            .parallelize_values_with(rows, 4)
            .map_named("bench.dot")
            .encoded();
        suite.bench_throughput("plan_ir_decoded", Throughput::Items(ROWS as u64), move || {
            let decoded: PlanSpec = from_bytes(&plan_bytes).unwrap();
            let total = sc_plan.plan_rdd(decoded).sum_f64().unwrap();
            black_box(total);
        });
    }

    // --- single-threaded reference (floor) ------------------------------
    {
        let mat = mat.clone();
        let x = x.clone();
        suite.bench_throughput("single_thread_floor", Throughput::Items(ROWS as u64), move || {
            let total: f64 = mat
                .iter()
                .map(|row| row.iter().zip(x.iter()).map(|(a, b)| a * b).sum::<f64>())
                .sum();
            black_box(total);
        });
    }

    suite.report();
}
