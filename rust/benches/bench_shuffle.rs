//! E9 — shuffle tier throughput: reading a full shuffle's buckets from
//! the in-memory tier vs forced-spill disk read-back vs remote fetch over
//! the `shuffle.fetch` RPC endpoint.
//!
//! Expected shape: memory ≫ disk > remote; the remote path adds one RPC
//! round trip per bucket on top of the serving worker's local read, so
//! its gap versus disk is the network/framing cost the DataMPI line of
//! work identifies as the dominant shuffle term.
//!
//! Run: `cargo bench --bench bench_shuffle` (MPIGNITE_BENCH_FAST=1 to
//! smoke). CSV block feeds CHANGES.md baselines.

use mpignite::bench::{black_box, BenchSuite, Throughput};
use mpignite::cluster::{Master, Worker};
use mpignite::config::IgniteConf;
use mpignite::ser::to_bytes;
use mpignite::shuffle::ShuffleManager;
use mpignite::storage::DiskStore;
use std::sync::Arc;
use std::time::Duration;

const MAPS: usize = 8;
const REDUCES: usize = 4;
const PAIRS_PER_BUCKET: usize = 128;

/// Deterministic bucket payload for (map, reduce).
fn bucket(map: usize, reduce: usize) -> Vec<(u64, u64)> {
    (0..PAIRS_PER_BUCKET)
        .map(|i| {
            let k = (map * 1_000 + reduce * 100 + i) as u64;
            (k, k.wrapping_mul(0x9E37_79B9))
        })
        .collect()
}

/// Total encoded bytes of one full shuffle (the throughput denominator).
fn shuffle_bytes() -> u64 {
    let mut total = 0u64;
    for m in 0..MAPS {
        for r in 0..REDUCES {
            total += to_bytes(&bucket(m, r)).len() as u64;
        }
    }
    total
}

fn fill(sm: &ShuffleManager, shuffle: u64) {
    for m in 0..MAPS {
        for r in 0..REDUCES {
            sm.put_bucket(shuffle, m, r, bucket(m, r));
        }
        sm.map_done(shuffle, m, MAPS).unwrap();
    }
}

/// Read every bucket of the shuffle back, whatever tier it lives in.
fn drain(sm: &ShuffleManager, shuffle: u64) -> u64 {
    let mut acc = 0u64;
    for m in 0..MAPS {
        for r in 0..REDUCES {
            let b: Vec<(u64, u64)> = sm.fetch_bucket(shuffle, m, r).unwrap();
            acc = acc.wrapping_add(b.len() as u64);
        }
    }
    acc
}

fn main() {
    mpignite::util::init_logger();
    let bytes = shuffle_bytes();
    let mut suite = BenchSuite::new(format!(
        "E9: shuffle tier read throughput ({MAPS} maps x {REDUCES} reduces, {} B/shuffle)",
        bytes
    ));

    // --- tier 1: in-memory (unbounded budget, no disk) ----------------
    {
        let sm = ShuffleManager::default();
        fill(&sm, 1);
        assert_eq!(sm.spilled_count(), 0);
        suite.bench_throughput("read_in_memory", Throughput::Bytes(bytes), move || {
            black_box(drain(&sm, 1));
        });
    }

    // --- tier 2: forced spill (budget 0, every read hits disk) --------
    {
        let disk = Arc::new(DiskStore::new("/tmp/mpignite-bench-shuffle").unwrap());
        let sm = ShuffleManager::new(0, Some(disk));
        fill(&sm, 2);
        assert_eq!(sm.spilled_count(), MAPS * REDUCES, "budget 0 spills every bucket");
        suite.bench_throughput("read_forced_spill", Throughput::Bytes(bytes), move || {
            black_box(drain(&sm, 2));
        });
    }

    // --- tier 3: remote fetch over shuffle.fetch RPC -------------------
    {
        let conf = IgniteConf::new();
        let master = Master::start(&conf, 0).expect("master");
        let producer = Worker::start(&conf, master.address()).expect("producer worker");
        let consumer = Worker::start(&conf, master.address()).expect("consumer worker");
        master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

        // The producer holds every map output; the consumer holds none,
        // so each drained bucket crosses the RPC plane.
        fill(&producer.engine().shuffle, 3);
        let consumer_sm = consumer.engine().clone();
        suite.bench_throughput("read_remote_fetch", Throughput::Bytes(bytes), move || {
            black_box(drain(&consumer_sm.shuffle, 3));
        });
        let remote = mpignite::metrics::global().counter("shuffle.remote.fetches").get();
        assert!(remote >= (MAPS * REDUCES) as u64, "remote tier must be exercised");
        master.shutdown();
    }

    suite.report();
}
