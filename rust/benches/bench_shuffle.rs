//! E9 — shuffle fast-path throughput: reading a full shuffle's buckets
//! from the in-memory tier vs forced-spill disk read-back vs remote fetch
//! over the shuffle RPC endpoints, each with and without LZ block
//! compression; remote fetch per-bucket (`shuffle.fetch`) vs batched
//! streaming (`shuffle.fetch_multi`); and a 2-worker plan job with
//! locality-aware vs round-robin reduce placement.
//!
//! Expected shape: memory ≫ disk > remote; compression trades CPU for
//! bytes (wins grow with payload redundancy and with slower tiers);
//! batched fetch removes per-bucket round-trips so its gap over the
//! per-bucket lane is pure RPC overhead; the locality lane removes
//! remote fetches entirely for well-placed reduces.
//!
//! Run: `cargo bench --bench bench_shuffle` (MPIGNITE_BENCH_FAST=1 to
//! smoke). CSV block feeds CHANGES.md baselines.

use mpignite::bench::{black_box, BenchSuite, Throughput};
use mpignite::cluster::{Master, Worker};
use mpignite::config::IgniteConf;
use mpignite::rdd::{AggSpec, PlanSpec};
use mpignite::ser::{to_bytes, Value};
use mpignite::shuffle::{ShuffleManager, DEFAULT_FETCH_BATCH_BYTES};
use mpignite::storage::DiskStore;
use std::sync::Arc;
use std::time::Duration;

const MAPS: usize = 8;
const REDUCES: usize = 4;
const PAIRS_PER_BUCKET: usize = 128;

/// Deterministic bucket payload for (map, reduce).
fn bucket(map: usize, reduce: usize) -> Vec<(u64, u64)> {
    (0..PAIRS_PER_BUCKET)
        .map(|i| {
            let k = (map * 1_000 + reduce * 100 + i) as u64;
            (k, k.wrapping_mul(0x9E37_79B9))
        })
        .collect()
}

/// Total encoded bytes of one full shuffle (the throughput denominator).
fn shuffle_bytes() -> u64 {
    let mut total = 0u64;
    for m in 0..MAPS {
        for r in 0..REDUCES {
            total += to_bytes(&bucket(m, r)).len() as u64;
        }
    }
    total
}

fn fill(sm: &ShuffleManager, shuffle: u64) {
    for m in 0..MAPS {
        for r in 0..REDUCES {
            sm.put_bucket(shuffle, m, r, bucket(m, r));
        }
        sm.map_done(shuffle, m, MAPS).unwrap();
    }
}

/// Read every bucket of the shuffle back one at a time, whatever tier it
/// lives in (the per-bucket baseline).
fn drain(sm: &ShuffleManager, shuffle: u64) -> u64 {
    let mut acc = 0u64;
    for m in 0..MAPS {
        for r in 0..REDUCES {
            let b: Vec<(u64, u64)> = sm.fetch_bucket(shuffle, m, r).unwrap();
            acc = acc.wrapping_add(b.len() as u64);
        }
    }
    acc
}

/// Read the shuffle reduce-side: one batched streaming pull per reduce
/// partition (the `fetch_multi` fast path).
fn drain_batched(sm: &ShuffleManager, shuffle: u64) -> u64 {
    let mut acc = 0u64;
    for r in 0..REDUCES {
        let framed = sm.fetch_reduce_bytes(shuffle, r, MAPS).unwrap();
        for f in &framed {
            let b: Vec<(u64, u64)> = mpignite::shuffle::decode_bucket(f).unwrap();
            acc = acc.wrapping_add(b.len() as u64);
        }
    }
    acc
}

/// One 4-map × 4-reduce plan wordcount (fresh shuffle id per call so
/// back-to-back jobs never see stale completion state).
fn locality_plan() -> PlanSpec {
    let partitions: Vec<Vec<Value>> = (0..4)
        .map(|p| {
            (0..100)
                .map(|i| {
                    Value::List(vec![
                        Value::Str(format!("key-{:02}", (p * 100 + i) % 40)),
                        Value::I64(i as i64),
                    ])
                })
                .collect()
        })
        .collect();
    PlanSpec::Shuffle {
        shuffle_id: mpignite::util::next_id(),
        partitions: 4,
        agg: AggSpec::SumI64,
        parent: Arc::new(PlanSpec::Source { partitions }),
    }
}

fn bench_locality_lane(suite: &mut BenchSuite, name: &str, locality: bool) {
    let mut conf = IgniteConf::new();
    conf.set("ignite.plan.locality", if locality { "true" } else { "false" });
    let master = Master::start(&conf, 0).expect("master");
    let _workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&conf, master.address()).expect("worker")).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();
    let m = master.clone();
    suite.bench(name, move || {
        let parts = m.run_plan(&locality_plan()).unwrap();
        black_box(parts.len());
    });
    master.shutdown();
}

fn main() {
    mpignite::util::init_logger();
    let bytes = shuffle_bytes();
    let mut suite = BenchSuite::new(format!(
        "E9: shuffle fast-path read throughput ({MAPS} maps x {REDUCES} reduces, {} B/shuffle)",
        bytes
    ));

    // --- tier 1: in-memory (unbounded budget, no disk) ----------------
    {
        let sm = ShuffleManager::default();
        fill(&sm, 1);
        assert_eq!(sm.spilled_count(), 0);
        suite.bench_throughput("read_in_memory", Throughput::Bytes(bytes), move || {
            black_box(drain(&sm, 1));
        });
    }

    // --- tier 1 + LZ: in-memory, compressed frames --------------------
    {
        let sm = ShuffleManager::with_options(usize::MAX, None, true, DEFAULT_FETCH_BATCH_BYTES);
        fill(&sm, 11);
        suite.bench_throughput("read_in_memory_lz", Throughput::Bytes(bytes), move || {
            black_box(drain(&sm, 11));
        });
    }

    // --- tier 2: forced spill (budget 0, every read hits disk) --------
    {
        let disk = Arc::new(DiskStore::new("/tmp/mpignite-bench-shuffle").unwrap());
        let sm = ShuffleManager::new(0, Some(disk));
        fill(&sm, 2);
        assert_eq!(sm.spilled_count(), MAPS * REDUCES, "budget 0 spills every bucket");
        suite.bench_throughput("read_forced_spill", Throughput::Bytes(bytes), move || {
            black_box(drain(&sm, 2));
        });
    }

    // --- tier 2 + LZ: forced spill with compressed frames (less disk) --
    {
        let disk = Arc::new(DiskStore::new("/tmp/mpignite-bench-shuffle-lz").unwrap());
        let sm = ShuffleManager::with_options(0, Some(disk), true, DEFAULT_FETCH_BATCH_BYTES);
        fill(&sm, 12);
        assert_eq!(sm.spilled_count(), MAPS * REDUCES);
        suite.bench_throughput("read_forced_spill_lz", Throughput::Bytes(bytes), move || {
            black_box(drain(&sm, 12));
        });
    }

    // --- tier 3: remote fetch, one RPC per bucket ----------------------
    {
        let conf = IgniteConf::new();
        let master = Master::start(&conf, 0).expect("master");
        let producer = Worker::start(&conf, master.address()).expect("producer worker");
        let consumer = Worker::start(&conf, master.address()).expect("consumer worker");
        master.wait_for_workers(2, Duration::from_secs(5)).unwrap();

        // The producer holds every map output; the consumer holds none,
        // so each drained bucket crosses the RPC plane.
        fill(&producer.engine().shuffle, 3);
        let consumer_sm = consumer.engine().clone();
        suite.bench_throughput("read_remote_fetch", Throughput::Bytes(bytes), move || {
            black_box(drain(&consumer_sm.shuffle, 3));
        });
        let remote = mpignite::metrics::global().counter("shuffle.remote.fetches").get();
        assert!(remote >= (MAPS * REDUCES) as u64, "remote tier must be exercised");

        // --- tier 3 batched: one streaming fetch_multi per worker ------
        fill(&producer.engine().shuffle, 13);
        let consumer_sm = consumer.engine().clone();
        let multi_before =
            mpignite::metrics::global().counter("shuffle.fetch.multi.calls").get();
        suite.bench_throughput("read_remote_fetch_batched", Throughput::Bytes(bytes), move || {
            black_box(drain_batched(&consumer_sm.shuffle, 13));
        });
        assert!(
            mpignite::metrics::global().counter("shuffle.fetch.multi.calls").get()
                > multi_before,
            "batched lane must ride shuffle.fetch_multi"
        );
        master.shutdown();
    }

    // --- locality: plan-job latency with and without byte-aware placement
    bench_locality_lane(&mut suite, "plan_job_locality_on", true);
    bench_locality_lane(&mut suite, "plan_job_locality_off", false);

    suite.report();
}
