//! E14 — streaming micro-batch engine: batches/sec and batch-latency
//! percentiles through the job server on a real 2-worker in-process
//! cluster, across the axes the subsystem introduces:
//!
//! * **backpressure on vs off** — the same stream drained under the
//!   default in-flight cap (admission stalls when the cluster lags)
//!   versus a cap high enough that admission never blocks;
//! * **stateful vs stateless** — windowed aggregation (cross-batch
//!   state merged into the driver's shuffle tiers, watermark
//!   finalization + GC) versus plain per-batch reduction.
//!
//! One bench iteration = one full stream of `BATCHES` micro-batches
//! drained to completion, so the Items throughput column reads directly
//! as batches/sec. The p50/p99 batch latencies come from the engine's
//! own `streaming.batch.latency` histogram.
//!
//! Run: `cargo bench --bench bench_streaming` (MPIGNITE_BENCH_FAST=1 to
//! smoke). CSV block feeds CHANGES.md baselines.

use mpignite::bench::{black_box, BenchSuite, Throughput};
use mpignite::cluster::Worker;
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use std::sync::Arc;
use std::time::Duration;

const BATCHES: u64 = 20;
const PARTS: usize = 2;
const ROWS_PER_PART: usize = 32;
const KEYS: usize = 8;

fn cluster(max_inflight: usize) -> (IgniteContext, Vec<Arc<Worker>>) {
    let mut conf = IgniteConf::new();
    conf.set("ignite.worker.heartbeat.ms", "50");
    conf.set("ignite.streaming.max.inflight.batches", max_inflight.to_string());
    let sc = IgniteContext::cluster_driver(conf.clone(), 0).expect("driver");
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&conf, master.address()).expect("worker")).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();
    (sc, workers)
}

fn source() -> MemoryStreamSource {
    let src = MemoryStreamSource::new();
    for t in 0..BATCHES {
        let parts: Vec<Vec<Value>> = (0..PARTS)
            .map(|p| {
                (0..ROWS_PER_PART)
                    .map(|i| {
                        Value::List(vec![
                            Value::Str(format!("k{}", (i + p) % KEYS)),
                            Value::I64(1),
                        ])
                    })
                    .collect()
            })
            .collect();
        src.push(parts, t);
    }
    src.close();
    src
}

/// Drain one full stream; returns the result-row count (for black_box).
fn run_stream(sc: &IgniteContext, windowed: bool) -> usize {
    let mut spec = QuerySpec::reduce("bench.stream", Vec::new(), AggSpec::SumI64, PARTS);
    if windowed {
        spec = spec.windowed(WindowSpec::tumbling(4));
    }
    let mut query = sc.streaming().query(Box::new(source()), spec).expect("query");
    query.drain(Duration::from_secs(60)).expect("drain");
    assert_eq!(query.batches_completed(), BATCHES);
    query.results_sorted().len()
}

fn main() {
    mpignite::util::init_logger();
    let mut suite = BenchSuite::new(format!(
        "E14: streaming micro-batches through the job server \
         ({BATCHES} batches/stream, {PARTS}x{ROWS_PER_PART} rows, {KEYS} keys, 2 workers)"
    ));

    {
        let (sc, _workers) = cluster(2);
        suite.bench_throughput("stateless_backpressure_cap2", Throughput::Items(BATCHES), || {
            black_box(run_stream(&sc, false));
        });
        sc.master().unwrap().shutdown();
    }

    {
        let (sc, _workers) = cluster(64);
        suite.bench_throughput("stateless_backpressure_off", Throughput::Items(BATCHES), || {
            black_box(run_stream(&sc, false));
        });
        sc.master().unwrap().shutdown();
    }

    {
        let (sc, _workers) = cluster(2);
        suite.bench_throughput("stateful_windowed_cap2", Throughput::Items(BATCHES), || {
            black_box(run_stream(&sc, true));
        });
        sc.master().unwrap().shutdown();
    }

    suite.report();

    let m = mpignite::metrics::global();
    let latency = m.histogram("streaming.batch.latency");
    println!(
        "\nbatch latency over {} batches: p50 {}us p99 {}us max {}us",
        latency.count(),
        latency.quantile_ns(0.5) / 1_000,
        latency.quantile_ns(0.99) / 1_000,
        latency.max_ns() / 1_000,
    );
    println!(
        "submitted {} completed {} backpressure stalls {} windows finalized {}",
        m.counter("streaming.batches.submitted").get(),
        m.counter("streaming.batches.completed").get(),
        m.counter("streaming.backpressure.stalls").get(),
        m.counter("streaming.windows.finalized").get(),
    );
}
