//! E11 — broadcast plane: stage shipping with inline sources vs
//! broadcast `SourceRef` sources, on a real 2-worker in-process cluster.
//!
//! The inline lane re-ships the full encoded source inside every
//! stage's `task.run` RPC (once per stage per worker); the broadcast
//! lane ships a plan skeleton and each worker pulls the source's blocks
//! over its wire once per job (peer-preferring, cached across stages).
//! Expected shape: broadcast wins and its margin grows with stage count
//! and worker count; the printed `broadcast.bytes.fetched.*` split
//! shows how much of the traffic the peers absorbed from the driver.
//!
//! Run: `cargo bench --bench bench_broadcast` (MPIGNITE_BENCH_FAST=1 to
//! smoke). CSV block feeds CHANGES.md baselines.

use mpignite::bench::{black_box, BenchSuite, Throughput};
use mpignite::closure::register_op;
use mpignite::cluster::Worker;
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use mpignite::rdd::AggSpec;
use std::sync::Arc;
use std::time::Duration;

const ROWS: usize = 2000;
const PARTS: usize = 4;

fn register_ops() {
    register_op("bench.bcast.pair", |v| Ok(Value::List(vec![v, Value::I64(1)])));
}

fn source_rows() -> Vec<Value> {
    (0..ROWS as i64).map(|x| Value::Str(format!("key-{:05}", x % 97))).collect()
}

/// One multi-stage plan job: map → reduce_by_key → reduce_by_key.
fn run_job(sc: &IgniteContext) -> usize {
    sc.parallelize_values_with(source_rows(), PARTS)
        .map_named("bench.bcast.pair")
        .reduce_by_key(3, AggSpec::SumI64)
        .reduce_by_key(2, AggSpec::First)
        .collect()
        .expect("bench job")
        .len()
}

fn cluster(auto_min_bytes: &str) -> (IgniteContext, Vec<Arc<Worker>>) {
    let mut conf = IgniteConf::new();
    conf.set("ignite.worker.heartbeat.ms", "50");
    conf.set("ignite.broadcast.auto.min.bytes", auto_min_bytes);
    let sc = IgniteContext::cluster_driver(conf.clone(), 0).expect("driver");
    let master = sc.master().unwrap().clone();
    let workers: Vec<Arc<Worker>> =
        (0..2).map(|_| Worker::start(&conf, master.address()).expect("worker")).collect();
    master.wait_for_workers(2, Duration::from_secs(5)).unwrap();
    (sc, workers)
}

fn main() {
    mpignite::util::init_logger();
    register_ops();
    let src_bytes = mpignite::ser::to_bytes(&source_rows()).len() as u64;
    let mut suite = BenchSuite::new(format!(
        "E11: plan stage shipping, inline vs broadcast source ({ROWS} rows, {src_bytes} B encoded, 2 workers, 3 stages)"
    ));

    // --- lane 1: inline sources (threshold never reached) --------------
    {
        let (sc, _workers) = cluster("1073741824");
        suite.bench_throughput("job_inline_source", Throughput::Bytes(src_bytes), || {
            black_box(run_job(&sc));
        });
        sc.master().unwrap().shutdown();
    }

    // --- lane 2: broadcast SourceRef (every source ships by id) --------
    {
        let (sc, _workers) = cluster("1");
        suite.bench_throughput("job_broadcast_source", Throughput::Bytes(src_bytes), || {
            black_box(run_job(&sc));
        });
        let peer = mpignite::metrics::global().counter("broadcast.bytes.fetched.peer").get();
        let master = mpignite::metrics::global().counter("broadcast.bytes.fetched.master").get();
        println!(
            "broadcast fetch split: {peer} B from peers, {master} B from the master/driver"
        );
        sc.master().unwrap().shutdown();
    }

    suite.report();
}
