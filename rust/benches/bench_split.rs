//! E4 — communicator split cost: the gather-sort-broadcast protocol at
//! the lowest participating rank (paper §3.1), vs ranks and color count.
//!
//! Expected shape: linear in ranks (root receives N reports and sends N
//! results); color count barely matters (same message volume).

use mpignite::bench::time_world_op;
use mpignite::util::{fmt_duration, Table};

fn main() {
    mpignite::util::init_logger();
    let fast = std::env::var("MPIGNITE_BENCH_FAST").is_ok();
    let iters = if fast { 20 } else { 200 };

    println!("\n== E4: split(color, key) latency ==");
    let mut t = Table::new(vec!["ranks", "colors", "split latency"]);
    let mut csv = Table::new(vec!["ranks", "colors", "split_ns"]);
    for n in [4usize, 16, 64] {
        for colors in [1usize, 4, 8] {
            if colors > n {
                continue;
            }
            let d = time_world_op(n, iters, move |comm, _| {
                let sub = comm
                    .split((comm.rank() % colors) as i64, comm.rank() as i64)
                    .unwrap();
                std::hint::black_box(sub.size());
            });
            t.row(vec![n.to_string(), colors.to_string(), fmt_duration(d)]);
            csv.row(vec![n.to_string(), colors.to_string(), d.as_nanos().to_string()]);
        }
    }
    print!("{}", t.render());
    println!("\n-- csv --\n{}", csv.to_csv());
}
