//! E6 — endpoint-cache amortization: the paper's claim that maintaining
//! a collection of RPC endpoints "augmented on an as-needed basis ...
//! amortizes the cost of sending to new worker nodes".
//!
//! Cold = connections dropped before every ask (re-dial + handshake);
//! warm = cached connection reused. Expected shape: warm ≪ cold.

use mpignite::bench::{BenchSuite, Throughput};
use mpignite::metrics;
use mpignite::rpc::{Envelope, RpcEnv};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    mpignite::util::init_logger();
    let server = RpcEnv::server("bench-server", 0).unwrap();
    server.register("echo", Arc::new(|env: &Envelope| Ok(Some(env.body.clone().into()))));
    let addr = server.address();

    let mut suite = BenchSuite::new("E6: endpoint establishment vs cached connection");

    {
        let client = RpcEnv::client("bench-cold");
        let addr = addr.clone();
        suite.bench("cold_ask (drop connections each time)", move || {
            client.drop_connections();
            let _ = client.ask(&addr, "echo", vec![0u8; 64], Duration::from_secs(5)).unwrap();
        });
    }
    {
        let client = RpcEnv::client("bench-warm");
        let addr = addr.clone();
        // Prime once.
        let _ = client.ask(&addr, "echo", vec![0u8; 64], Duration::from_secs(5)).unwrap();
        suite.bench("warm_ask (cached connection)", move || {
            let _ = client.ask(&addr, "echo", vec![0u8; 64], Duration::from_secs(5)).unwrap();
        });
    }
    {
        // One-way sends on a warm connection (pure transport cost).
        let client = RpcEnv::client("bench-oneway");
        let addr = addr.clone();
        let _ = client.ask(&addr, "echo", vec![], Duration::from_secs(5)).unwrap();
        suite.bench_throughput(
            "warm_one_way_send (64 B)",
            Throughput::Bytes(64),
            move || {
                client.send(&addr, "echo", vec![0u8; 64]).unwrap();
            },
        );
    }

    suite.report();
    let cold = suite.results()[0].median;
    let warm = suite.results()[1].median;
    println!(
        "\namortization factor: cold/warm = {:.1}x  (connections established: {})",
        cold.as_secs_f64() / warm.as_secs_f64(),
        metrics::global().counter("rpc.conn.established").get()
    );
    server.shutdown();
}
