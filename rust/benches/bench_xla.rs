//! E8 — the compute hot path: AOT Pallas matvec artifacts through PJRT
//! vs a naive pure-Rust matvec, across matrix sizes.
//!
//! Expected shape: XLA wins increasingly with size (vectorized dot loops
//! vs scalar loop); the artifact path's fixed overhead (channel round
//! trip + literal marshalling) dominates at tiny sizes.
//!
//! Requires `make artifacts`; exits 0 with a notice otherwise.

use mpignite::bench::{black_box, BenchSuite, Throughput};
use mpignite::rng::Xoshiro256;
use mpignite::runtime::{shared_service, TensorF32};

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::seeded(seed);
    (0..n).map(|_| rng.next_f32() - 0.5).collect()
}

fn naive_matvec(a: &[f32], x: &[f32], n: usize) -> Vec<f32> {
    let mut y = vec![0f32; n];
    for i in 0..n {
        let row = &a[i * n..(i + 1) * n];
        let mut acc = 0f32;
        for j in 0..n {
            acc += row[j] * x[j];
        }
        y[i] = acc;
    }
    y
}

fn main() {
    mpignite::util::init_logger();
    let svc = match shared_service("artifacts") {
        Ok(s) => s,
        Err(e) => {
            println!("bench_xla skipped: {e}");
            return;
        }
    };

    let mut suite = BenchSuite::new("E8: Pallas/XLA artifact vs naive Rust matvec");
    for n in [256usize, 512, 1024] {
        let a = rand_vec(n * n, 1);
        let x = rand_vec(n, 2);
        let flops = (2 * n * n) as u64;

        // Correctness cross-check first (runtime vs naive).
        let name = format!("matvec_f32_{n}x{n}");
        let y_xla = svc
            .matvec(&name, TensorF32::matrix(a.clone(), n, n), TensorF32::vec(x.clone()))
            .unwrap();
        let y_ref = naive_matvec(&a, &x, n);
        for i in 0..n {
            assert!(
                (y_xla[i] - y_ref[i]).abs() < 1e-2 * (1.0 + y_ref[i].abs()),
                "mismatch at {i}: {} vs {}",
                y_xla[i],
                y_ref[i]
            );
        }

        {
            let (a, x) = (a.clone(), x.clone());
            suite.bench_throughput(
                format!("naive_rust_{n}x{n}"),
                Throughput::Items(flops),
                move || {
                    black_box(naive_matvec(&a, &x, n));
                },
            );
        }
        {
            let svc = svc.clone();
            let (a, x) = (a.clone(), x.clone());
            let name2 = name.clone();
            suite.bench_throughput(
                format!("xla_artifact_{n}x{n}"),
                Throughput::Items(flops),
                move || {
                    let y = svc
                        .matvec(
                            &name2,
                            TensorF32::matrix(a.clone(), n, n),
                            TensorF32::vec(x.clone()),
                        )
                        .unwrap();
                    black_box(y);
                },
            );
        }
        {
            // §Perf variant: the matrix lives in a cached device buffer;
            // only the vector is marshalled per call.
            let svc = svc.clone();
            let a = std::sync::Arc::new(TensorF32::matrix(a.clone(), n, n));
            let x = x.clone();
            let key = format!("bench.tile.{n}");
            suite.bench_throughput(
                format!("xla_cached_tile_{n}x{n}"),
                Throughput::Items(flops),
                move || {
                    let y = svc
                        .matvec_cached(&name, &key, &a, TensorF32::vec(x.clone()))
                        .unwrap();
                    black_box(y);
                },
            );
        }
    }
    suite.report();
    println!("\n(throughput items = flops; compare xla vs naive rows per size)");
}
