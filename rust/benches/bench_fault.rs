//! E7 — fault tolerance costs: task retry, straggler speculation, lost
//! shuffle output recomputation, and worker loss with the paper's
//! p2p→relay recovery fallback (single-shot timings, not steady-state).
//!
//! Expected shape: fault-free < retry < recompute; speculation caps the
//! straggler's impact near the straggler threshold instead of its full
//! delay; worker-loss recovery completes the job on survivors.

use mpignite::cluster::{Master, Worker};
use mpignite::config::IgniteConf;
use mpignite::prelude::*;
use mpignite::scheduler::Engine;
use mpignite::util::{fmt_duration, Stopwatch, Table};
use std::sync::Arc;
use std::time::Duration;

fn engine(slots: usize, speculation: bool) -> Arc<Engine> {
    let mut conf = IgniteConf::new();
    conf.set("ignite.worker.slots", slots.to_string());
    conf.set("ignite.task.speculation", if speculation { "true" } else { "false" });
    conf.set("ignite.task.speculation.multiplier", "3.0");
    Engine::new(conf).unwrap()
}

fn run_job(eng: &Arc<Engine>, stage: u64) -> Duration {
    let sw = Stopwatch::start();
    eng.run_task_set(stage, 16, |_p| {
        std::hint::black_box((0..20_000u64).sum::<u64>());
        Ok(())
    })
    .unwrap();
    sw.elapsed()
}

fn main() {
    mpignite::util::init_logger();
    println!("\n== E7: fault handling costs (16 tasks, 4 slots) ==");
    let mut t = Table::new(vec!["scenario", "job time", "notes"]);

    // Baseline.
    let eng = engine(4, false);
    let base = run_job(&eng, 1);
    t.row(vec!["fault-free".into(), fmt_duration(base), String::new()]);

    // One injected task failure (retry absorbs it).
    let eng = engine(4, false);
    eng.fault.fail_task(2, 3, 0);
    let with_retry = run_job(&eng, 2);
    t.row(vec!["1 injected task failure".into(), fmt_duration(with_retry), "retry".into()]);

    // Straggler without speculation: pays the full 150ms delay.
    let eng = engine(4, false);
    eng.fault.delay_task(3, 0, Duration::from_millis(150));
    let slow = run_job(&eng, 3);
    t.row(vec![
        "150ms straggler, speculation OFF".into(),
        fmt_duration(slow),
        "pays full delay".into(),
    ]);

    // Straggler with speculation: copy rescues it.
    let eng = engine(4, true);
    eng.fault.delay_task(4, 0, Duration::from_millis(150));
    let rescued = run_job(&eng, 4);
    t.row(vec![
        "150ms straggler, speculation ON".into(),
        fmt_duration(rescued),
        "copy rescues".into(),
    ]);

    // Lost shuffle output → lineage recompute.
    let eng = engine(4, false);
    let sc_conf = {
        let mut c = IgniteConf::new();
        c.set("ignite.worker.slots", "4");
        c
    };
    let _ = sc_conf;
    {
        use mpignite::scheduler::StageSpec;
        let stage = StageSpec {
            shuffle_id: 77,
            num_tasks: 8,
            run_task: Arc::new(|map_idx, eng: &Engine| {
                std::hint::black_box((0..50_000u64).sum::<u64>());
                eng.shuffle.put_bucket(77, map_idx, 0, vec![map_idx as u64]);
                eng.shuffle.map_done(77, map_idx, 8)
            }),
        };
        let sw = Stopwatch::start();
        eng.run_stages(std::slice::from_ref(&stage)).unwrap();
        let first = sw.elapsed();
        // Lose one map output; re-running the stage recomputes.
        eng.shuffle.lose_map_output(77, 3);
        let sw = Stopwatch::start();
        eng.run_stages(std::slice::from_ref(&stage)).unwrap();
        let recompute = sw.elapsed();
        t.row(vec!["shuffle stage first run".into(), fmt_duration(first), "8 map tasks".into()]);
        t.row(vec![
            "recompute after losing 1 map output".into(),
            fmt_duration(recompute),
            "lineage".into(),
        ]);
    }

    // Worker loss mid-cluster → relay recovery (paper's mode switch).
    {
        mpignite::closure::register_parallel_fn("bench.fault.allreduce", |comm, _| {
            let v = comm.all_reduce(1i64, |a, b| a + b)?;
            Ok(Value::I64(v))
        });
        let mut conf = IgniteConf::new();
        conf.set("ignite.worker.heartbeat.ms", "50");
        conf.set("ignite.worker.timeout.ms", "300");
        let master = Master::start(&conf, 0).unwrap();
        let workers: Vec<_> =
            (0..3).map(|_| Worker::start(&conf, master.address()).unwrap()).collect();
        master.wait_for_workers(3, Duration::from_secs(5)).unwrap();

        let sw = Stopwatch::start();
        master.execute_named("bench.fault.allreduce", 6, Value::Unit).unwrap();
        let healthy = sw.elapsed();

        workers[2].kill();
        std::thread::sleep(Duration::from_millis(400)); // let loss register
        let sw = Stopwatch::start();
        let out = master.execute_named("bench.fault.allreduce", 6, Value::Unit).unwrap();
        let after_loss = sw.elapsed();
        assert_eq!(out[0], Value::I64(6));
        t.row(vec!["cluster job, 3 workers healthy".into(), fmt_duration(healthy), String::new()]);
        t.row(vec![
            "cluster job after killing 1 of 3".into(),
            fmt_duration(after_loss),
            "survivors (+relay fallback on mid-job loss)".into(),
        ]);
        master.shutdown();
    }

    print!("{}", t.render());
}
