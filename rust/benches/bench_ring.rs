//! E2 — ring latency scaling (the paper's Listing 2 pattern).
//!
//! One token traverses an N-rank ring; reported latency is per full
//! traversal, so the expected shape is ~linear in N (each hop is one
//! mailbox enqueue + wakeup in local mode).
//!
//! Run: `cargo bench --bench bench_ring` (MPIGNITE_BENCH_FAST=1 to smoke).

use mpignite::bench::time_world_op;
use mpignite::util::{fmt_duration, Table};

fn ring_once(comm: &mpignite::comm::SparkComm, tag: i64) {
    let rank = comm.rank();
    let size = comm.size();
    if size == 1 {
        return;
    }
    if rank == 0 {
        comm.send(1, tag, 1i64).unwrap();
        let _: i64 = comm.receive((size - 1) as i64, tag).unwrap();
    } else {
        let t: i64 = comm.receive((rank - 1) as i64, tag).unwrap();
        comm.send((rank + 1) % size, tag, t).unwrap();
    }
}

fn main() {
    mpignite::util::init_logger();
    let fast = std::env::var("MPIGNITE_BENCH_FAST").is_ok();
    let iters = if fast { 50 } else { 500 };

    let mut table = Table::new(vec!["ranks", "ring traversal", "per hop"]);
    let mut csv = Table::new(vec!["ranks", "traversal_ns", "per_hop_ns"]);
    for n in [2usize, 4, 8, 16, 32, 64] {
        let per_iter = time_world_op(n, iters, |comm, i| ring_once(comm, (i % 1000) as i64));
        let per_hop = per_iter / n as u32;
        table.row(vec![n.to_string(), fmt_duration(per_iter), fmt_duration(per_hop)]);
        csv.row(vec![
            n.to_string(),
            per_iter.as_nanos().to_string(),
            per_hop.as_nanos().to_string(),
        ]);
    }
    println!("\n== E2: ring latency vs ranks (local transport) ==");
    print!("{}", table.render());
    println!("\n-- csv --\n{}", csv.to_csv());
}
