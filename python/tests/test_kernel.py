"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

Hypothesis sweeps shapes and values; fixed cases pin the block-boundary
edge cases. This is the core correctness signal for the compute layer —
the Rust runtime executes exactly what these kernels lower to.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matvec as mv
from compile.kernels import reduce as red
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ------------------------------------------------------------- matvec --


@pytest.mark.parametrize("m,k,bm,bk", [
    (4, 4, 4, 4),
    (8, 8, 4, 4),
    (16, 32, 8, 8),
    (128, 128, 128, 128),
    (256, 128, 128, 64),
])
def test_matvec_matches_ref_exact_blocks(m, k, bm, bk):
    a, x = rand((m, k), 1), rand((k,), 2)
    got = mv.matvec(a, x, block_m=bm, block_k=bk)
    np.testing.assert_allclose(got, ref.matvec(a, x), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k", [(1, 1), (3, 5), (7, 129), (130, 100), (5, 1024)])
def test_matvec_padded_arbitrary_shapes(m, k):
    a, x = rand((m, k), 3), rand((k,), 4)
    got = mv.matvec_padded(a, x)
    assert got.shape == (m,)
    np.testing.assert_allclose(got, ref.matvec(a, x), rtol=1e-4, atol=1e-4)


def test_matvec_rejects_non_divisible():
    with pytest.raises(ValueError):
        mv.matvec(rand((10, 10), 0), rand((10,), 1), block_m=4, block_k=4)


def test_matvec_identity():
    n = 64
    a = jnp.eye(n, dtype=jnp.float32)
    x = rand((n,), 5)
    np.testing.assert_allclose(mv.matvec(a, x, block_m=32, block_k=32), x, rtol=1e-6)


def test_matvec_zeros():
    a = jnp.zeros((32, 32), jnp.float32)
    x = rand((32,), 6)
    np.testing.assert_allclose(mv.matvec(a, x, block_m=32, block_k=32), jnp.zeros(32))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_matvec_padded_hypothesis_shapes(m, k, seed):
    a, x = rand((m, k), seed), rand((k,), seed + 1)
    got = mv.matvec_padded(a, x, block_m=16, block_k=16)
    np.testing.assert_allclose(got, ref.matvec(a, x), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(scale=st.floats(1e-3, 1e3), seed=st.integers(0, 2**31 - 1))
def test_matvec_scale_invariance(scale, seed):
    # (sA)·x == s(A·x) — catches accumulation-order bugs at magnitude.
    a, x = rand((32, 32), seed), rand((32,), seed + 1)
    got = mv.matvec(jnp.float32(scale) * a, x, block_m=16, block_k=16)
    want = jnp.float32(scale) * mv.matvec(a, x, block_m=16, block_k=16)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale)


def test_vmem_footprint_estimate_monotone():
    assert mv.vmem_footprint_bytes(128, 128) < mv.vmem_footprint_bytes(256, 256)
    # Default tile fits comfortably in ~16 MiB VMEM.
    assert mv.vmem_footprint_bytes() < 16 * 1024 * 1024


# ---------------------------------------------------------- reductions --


@pytest.mark.parametrize("n,block", [(4, 4), (256, 256), (1024, 256), (2048, 128)])
def test_dot_matches_ref(n, block):
    x, y = rand((n,), 7), rand((n,), 8)
    got = red.dot(x, y, block=block)
    np.testing.assert_allclose(got, ref.dot(x, y), rtol=1e-4, atol=1e-4)


def test_sumsq_and_norm():
    x = rand((512,), 9)
    np.testing.assert_allclose(red.sumsq(x, block=128), ref.sumsq(x), rtol=1e-5)
    np.testing.assert_allclose(red.norm(x, block=128), ref.norm(x), rtol=1e-5)


def test_dot_rejects_non_divisible():
    with pytest.raises(ValueError):
        red.dot(rand((10,), 0), rand((10,), 1), block=4)


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 16),
    block=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dot_hypothesis(n_blocks, block, seed):
    n = n_blocks * block
    x, y = rand((n,), seed), rand((n,), seed + 1)
    np.testing.assert_allclose(
        red.dot(x, y, block=block), ref.dot(x, y), rtol=1e-4, atol=1e-3
    )
