"""L2 correctness: the composed model graph vs the jnp oracle, plus the
distributed-decomposition identity the L3 coordinator relies on (sum of
row-block tile products == full product)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


def symmetric(n, seed):
    a = rand((n, n), seed)
    return (a + a.T) / 2


def test_power_iteration_step_matches_ref():
    a, x = symmetric(64, 0), rand((64,), 1)
    got_x, got_eig = model.power_iteration_step(a, x)
    want_x, want_eig = ref.power_iteration_step(a, x)
    np.testing.assert_allclose(got_x, want_x, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got_eig, want_eig, rtol=1e-4, atol=1e-4)


def test_power_iteration_converges_to_dominant_eigenpair():
    n = 96
    a = symmetric(n, 2)
    x = rand((n,), 3)
    x = x / jnp.linalg.norm(x)
    eig = 0.0
    for _ in range(200):
        x, eig = model.power_iteration_step(a, x)
    eigs = np.linalg.eigvalsh(np.asarray(a))
    dominant = eigs[np.argmax(np.abs(eigs))]
    np.testing.assert_allclose(float(eig), float(dominant), rtol=1e-3)
    # Residual ||Ax - λx|| is small.
    res = model.residual_norm(a, x, eig)
    assert float(res) < 1e-2


def test_row_block_decomposition_identity():
    """sum-free identity: concatenating per-rank row-block products equals
    the full product — what allGather over matvec_tile computes at L3."""
    n, ranks = 128, 4
    a, x = rand((n, n), 4), rand((n,), 5)
    rows = n // ranks
    parts = [model.matvec_tile(a[r * rows:(r + 1) * rows, :], x) for r in range(ranks)]
    got = jnp.concatenate(parts)
    np.testing.assert_allclose(got, ref.matvec(a, x), rtol=1e-4, atol=1e-4)


def test_normalize_unit_norm():
    y = rand((256,), 6)
    x = model.normalize(y)
    np.testing.assert_allclose(jnp.linalg.norm(x), 1.0, rtol=1e-5)


def test_axpy():
    x, y = rand((64,), 7), rand((64,), 8)
    np.testing.assert_allclose(model.axpy(2.5, x, y), 2.5 * x + y, rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([16, 32, 64]), seed=st.integers(0, 2**31 - 1))
def test_power_step_norm_is_one_hypothesis(n, seed):
    a, x = symmetric(n, seed), rand((n,), seed + 1)
    x_next, _ = model.power_iteration_step(a, x)
    np.testing.assert_allclose(jnp.linalg.norm(x_next), 1.0, rtol=1e-4)
