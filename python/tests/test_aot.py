"""AOT path checks: every entry point lowers to parseable HLO text with
the right parameter shapes, and the manifest stays consistent."""

import jax
import pytest

from compile import aot

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def eps():
    return aot.entry_points()


def test_entry_point_inventory(eps):
    names = set(eps)
    # The examples and benches depend on these exact names.
    for required in [
        "matvec_f32_64x64",
        "matvec_f32_256x256",
        "matvec_f32_1024x1024",
        "matvec_f32_128x1024",
        "matvec_f32_256x1024",
        "matvec_f32_4x4",
        "dot_f32_1024",
        "normalize_f32_1024",
        "power_step_f32_1024",
        "residual_norm_f32_1024",
    ]:
        assert required in names, f"missing entry point {required}"


@pytest.mark.parametrize("name", ["matvec_f32_64x64", "dot_f32_1024", "power_step_f32_1024"])
def test_lowering_produces_hlo_text(eps, name):
    fn, args, n_outputs = eps[name]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert "HloModule" in text, "not HLO text"
    assert "ENTRY" in text
    # return_tuple=True → the root is a tuple of n_outputs elements.
    assert text.count("parameter(") >= len(args)


def test_hlo_text_has_no_serialized_proto_markers(eps):
    # Guard against regressing to .serialize() (64-bit-id protos).
    fn, args, _ = eps["matvec_f32_64x64"]
    text = aot.to_hlo_text(jax.jit(fn).lower(*args))
    assert text.lstrip().startswith("HloModule")


def test_shape_desc():
    s = jax.ShapeDtypeStruct((3, 4), "float32")
    assert aot.shape_desc(s) == {"shape": [3, 4], "dtype": "float32"}


def test_entry_points_are_lowerable(eps):
    # Smoke-lower everything (cheap: tracing only, no compile).
    for name, (fn, args, _) in eps.items():
        lowered = jax.jit(fn).lower(*args)
        assert lowered is not None, name
