"""L2: the JAX compute graph composed from the L1 Pallas kernels.

Entry points here are what `aot.py` lowers to HLO text for the Rust
runtime. Everything is shape-static at lowering time; the L3 coordinator
chooses which artifact (shape variant) to execute.

The workload is the paper's running example scaled into a real driver:
distributed matrix-vector products (Listings 1/4) and the power-iteration
solver the E2E example runs, where each MPIgnite rank owns a row block of
A and computes its tile product with the L1 kernel, combining partial
vectors with `allReduce` at L3.
"""

import jax.numpy as jnp

from .kernels import matvec as mv
from .kernels import reduce as red


# Block shapes (§Perf): K-full row sweeps — (BM=256, BK=1024) keeps each
# grid step's VMEM residency ≈ 1 MiB (fits the ~16 MiB budget with double
# buffering), stays MXU-aligned, reads A exactly once (single pass, no
# output-block revisits), and minimizes interpret-mode grid overhead on
# the CPU PJRT backend (54.8 ms → 6.9 ms at 1024², see EXPERIMENTS.md).
BLOCK_M = 256
BLOCK_K = 1024


def matvec(a, x):
    """Full matrix-vector product via the tiled Pallas kernel."""
    return mv.matvec_padded(a, x, block_m=BLOCK_M, block_k=BLOCK_K)


def matvec_tile(a_tile, x):
    """One rank's row-block product: the per-rank compute of the 2D
    decomposition (Listing 4) and of the E2E power iteration."""
    return mv.matvec_padded(a_tile, x, block_m=BLOCK_M, block_k=BLOCK_K)


def dot(x, y):
    """Blocked dot product (Rayleigh quotient numerator at L3)."""
    return red.dot(x, y)


def normalize(y, eps=1e-12):
    """y / ||y|| with the norm from the blocked sum-of-squares kernel."""
    return y / (red.norm(y) + eps)


def power_iteration_step(a, x, eps=1e-12):
    """One whole-matrix power-iteration step (single-rank baseline):
    x ← A·x / ||A·x||, eigenvalue estimate via Rayleigh quotient."""
    y = mv.matvec_padded(a, x, block_m=BLOCK_M, block_k=BLOCK_K)
    x_next = y / (red.norm(y) + eps)
    eig = red.dot(x_next, mv.matvec_padded(a, x_next, block_m=BLOCK_M, block_k=BLOCK_K))
    return x_next, eig


def axpy(alpha, x, y):
    """alpha*x + y — fused by XLA; used for residual updates at L3."""
    return alpha * x + y


def residual_norm(a, x, eig):
    """||A·x − λ·x|| — convergence check for the E2E driver."""
    r = mv.matvec_padded(a, x, block_m=BLOCK_M, block_k=BLOCK_K) - eig * x
    return jnp.sqrt(red.sumsq(r))
