"""L1 Pallas kernels: blocked reductions (dot product, sum of squares).

These are the reduction primitives the L2 model composes for vector norms
(power-iteration normalization) and Rayleigh quotients. Each streams its
input through VMEM in 1-D blocks and accumulates a scalar (kept as a
(1, 1) block — TPU reductions want 2-D refs).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dot_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...] * y_ref[...]).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("block",))
def dot(x, y, *, block=256):
    """Blocked dot product of two equal-length f32 vectors."""
    (n,) = x.shape
    b = min(block, n)
    if n % b:
        raise ValueError(f"length {n} not divisible by block {b}")
    out = pl.pallas_call(
        _dot_kernel,
        grid=(n // b,),
        in_specs=[
            pl.BlockSpec((1, b), lambda i: (0, i)),
            pl.BlockSpec((1, b), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(x.reshape(1, n), y.reshape(1, n))
    return out.reshape(())


def sumsq(x, *, block=256):
    """Blocked sum of squares (squared L2 norm)."""
    return dot(x, x, block=block)


def norm(x, *, block=256):
    """L2 norm via the blocked sum-of-squares kernel."""
    return jnp.sqrt(sumsq(x, block=block))
