"""L1 Pallas kernel: tiled matrix-vector product.

The compute hot-spot of the paper's running example (Listings 1 and 4 are
both matrix-vector multiplication). TPU-shaped rather than GPU-shaped
(DESIGN.md §3 Hardware adaptation): the matrix streams through VMEM in
``(BM, BK)`` blocks declared by ``BlockSpec`` — the HBM→VMEM schedule that
a CUDA port would express with threadblocks — and each grid step feeds the
MXU a ``(BM, BK) @ (BK, 1)`` contraction, accumulating into a ``(BM, 1)``
output block that stays resident in VMEM across the K-sweep.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so lowering goes through the interpreter to plain HLO. The
BlockSpec structure (and hence the VMEM/MXU analysis in EXPERIMENTS.md
§Perf) is unchanged by interpretation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(a_ref, x_ref, o_ref):
    """One grid step: o[bm] += A[bm, bk] @ x[bk]."""

    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU contraction in f32; accumulate across the K grid dimension.
    o_ref[...] += jnp.dot(a_ref[...], x_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k"))
def matvec(a, x, *, block_m=128, block_k=128):
    """``y = A @ x`` via the tiled Pallas kernel.

    ``a``: (M, K) f32. ``x``: (K,) f32. Returns (M,) f32.
    Shapes must divide the block sizes; ``matvec_padded`` relaxes that.
    """
    m, k = a.shape
    bm = min(block_m, m)
    bk = min(block_k, k)
    if m % bm or k % bk:
        raise ValueError(f"shape ({m},{k}) not divisible by blocks ({bm},{bk})")
    x2 = x.reshape(k, 1)
    y2 = pl.pallas_call(
        _matvec_kernel,
        grid=(m // bm, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=True,
    )(a, x2)
    return y2.reshape(m)


def matvec_padded(a, x, *, block_m=128, block_k=128):
    """``matvec`` for arbitrary shapes: zero-pad up to block multiples.

    Zero padding preserves the product exactly (extra rows are sliced off,
    extra columns multiply zero entries of x).
    """
    m, k = a.shape
    bm = min(block_m, max(1, m))
    bk = min(block_k, max(1, k))
    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    x_p = jnp.pad(x, (0, kp - k))
    return matvec(a_p, x_p, block_m=bm, block_k=bk)[:m]


def vmem_footprint_bytes(block_m=128, block_k=128):
    """Estimated VMEM residency per grid step (f32): A block + x block +
    y block. Used by the §Perf roofline notes, not by execution."""
    return 4 * (block_m * block_k + block_k + block_m)
