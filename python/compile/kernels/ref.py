"""Pure-jnp oracles for every L1 kernel — the correctness ground truth the
pytest suite asserts against (`assert_allclose`)."""

import jax.numpy as jnp


def matvec(a, x):
    """y = A @ x."""
    return a @ x


def dot(x, y):
    return jnp.dot(x, y)


def sumsq(x):
    return jnp.dot(x, x)


def norm(x):
    return jnp.sqrt(jnp.dot(x, x))


def power_iteration_step(a, x, eps=1e-12):
    """One normalized power-iteration step + Rayleigh quotient."""
    y = a @ x
    nrm = jnp.sqrt(jnp.dot(y, y))
    x_next = y / (nrm + eps)
    eig = jnp.dot(x_next, a @ x_next)
    return x_next, eig
