"""AOT lowering: JAX (L2 + L1) → HLO **text** artifacts for the Rust
runtime.

HLO text, NOT ``lowered.compiler_ir(...).serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which xla_extension 0.5.1 (the
version the published ``xla`` crate binds) rejects (``proto.id() <=
INT_MAX``). The text parser reassigns ids and round-trips cleanly — see
/opt/xla-example/gen_hlo.py.

Python runs ONCE, at build time (`make artifacts`); the Rust binary is
self-contained afterwards.

Usage: ``python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_points():
    """Every artifact: name → (fn, example args, output arity).

    Shape variants cover the examples and benches: small tiles for the
    Listing-4 grid, bench tiles for E8, and the E2E power-iteration sizes
    (full matrix for the single-rank baseline; row blocks for 4/8 ranks).
    """
    eps = {}

    def add(name, fn, args, n_outputs):
        eps[name] = (fn, args, n_outputs)

    # Square matvecs (quickstart, E8 bench sweep).
    for n in (64, 256, 512, 1024):
        add(f"matvec_f32_{n}x{n}", model.matvec, (f32(n, n), f32(n)), 1)
    # Row-block matvecs for the distributed power iteration:
    # 1024-column matrix split over 4 or 8 ranks.
    for rows in (128, 256):
        add(f"matvec_f32_{rows}x1024", model.matvec_tile, (f32(rows, 1024), f32(1024)), 1)
    # Listing-4 style small tile.
    add("matvec_f32_4x4", model.matvec_tile, (f32(4, 4), f32(4)), 1)
    # Reductions.
    for n in (1024,):
        add(f"dot_f32_{n}", model.dot, (f32(n), f32(n)), 1)
        add(f"normalize_f32_{n}", model.normalize, (f32(n),), 1)
    # Whole-step baseline + convergence check.
    add("power_step_f32_1024", model.power_iteration_step, (f32(1024, 1024), f32(1024)), 2)
    add("residual_norm_f32_1024", model.residual_norm, (f32(1024, 1024), f32(1024), f32()), 1)
    return eps


def shape_desc(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", default=None, help="lower a single entry point")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {}
    for name, (fn, example_args, n_outputs) in sorted(entry_points().items()):
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest[name] = {
            "file": fname,
            "inputs": [shape_desc(a) for a in example_args],
            "n_outputs": n_outputs,
        }
        print(f"lowered {name}: {len(text)} chars")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    # Merge with an existing manifest when lowering a single entry.
    if args.only and os.path.exists(manifest_path):
        with open(manifest_path) as f:
            merged = json.load(f)
        merged.update(manifest)
        manifest = merged
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {manifest_path} ({len(manifest)} entries)")


if __name__ == "__main__":
    main()
